"""Break-rate invariants on the scenario lab (ISSUE 2 satellite): the
repo-level guarantees the robustness benchmark sweeps, pinned as tests.

Fast tier: at 40% byzantine on the seeded synthetic federation, plain
FedAvg's loss DIVERGES under ALIE and (aggregate-reversing) IPM — it
leaves the attack-free envelope by more than the break factor — while
BR-DRAG stays within 2x of its own attack-free trajectory, pointwise.

Slow tier (``-m slow``): a miniature scenario matrix through the actual
benchmark code path, checking the BENCH_robustness acceptance invariant
(trust-weighted BR-DRAG beats FedAvg in every byzantine cell).
"""
import dataclasses

import numpy as np
import pytest

from repro.adversary.scenarios import Scenario, run_cell, run_scenario

BYZ = 0.4
BREAK_FACTOR = 5.0
ATTACKS = {
    "alie": (),
    "ipm": (("eps", 2.0),),
}


def _pair(aggregator, attack, seed=0, **kw):
    attacked = run_scenario(
        Scenario(aggregator=aggregator, attack=attack,
                 attack_kw=ATTACKS[attack], malicious_fraction=BYZ, seed=seed, **kw)
    )
    clean = run_scenario(
        Scenario(aggregator=aggregator, attack="none",
                 malicious_fraction=BYZ, seed=seed, **kw)
    )
    return attacked, clean


@pytest.mark.parametrize("attack", sorted(ATTACKS))
def test_fedavg_breaks_under_adaptive_attacks(attack):
    """FedAvg at 40% byzantine: final loss leaves the attack-free
    envelope (the benchmark's 'broke' definition)."""
    attacked, clean = _pair("fedavg", attack)
    assert attacked["final_loss"] > BREAK_FACTOR * clean["final_loss"]


@pytest.mark.parametrize("attack", sorted(ATTACKS))
@pytest.mark.parametrize("seed", [0, 1])
def test_br_drag_stays_within_2x_of_attack_free(attack, seed):
    """BR-DRAG under the same attacks: the WHOLE trajectory stays within
    2x of the attack-free trajectory (after the transient of the first
    few rounds, where both are dominated by the far-out init)."""
    attacked, clean = _pair("br_drag", attack, seed=seed)
    ratio = attacked["losses"][3:] / np.maximum(clean["losses"][3:], 1e-9)
    assert np.isfinite(attacked["losses"]).all()
    assert float(ratio.max()) <= 2.0


def test_break_rate_cell_semantics():
    """run_cell flags fedavg/ipm as broken on every seed and br_drag on
    none — the two poles of the benchmark matrix."""
    sc = Scenario(aggregator="fedavg", attack="ipm", attack_kw=ATTACKS["ipm"],
                  malicious_fraction=BYZ)
    cell = run_cell(sc, BREAK_FACTOR, seeds=(0, 1))
    assert cell["break_rate"] == 1.0
    cell = run_cell(dataclasses.replace(sc, aggregator="br_drag"),
                    BREAK_FACTOR, seeds=(0, 1))
    assert cell["break_rate"] == 0.0


@pytest.mark.slow
def test_sharded_buffer_flood_invariant():
    """ISSUE 4 satellite: ``buffer_flood``'s hash-biased fast arrivals
    crowd a single pod's sub-buffer on the SHARDED async path — and the
    robustness-bench acceptance invariant must survive the layout
    change: trust-weighted BR-DRAG still beats plain FedAvg on final
    loss, and stays inside the break envelope of its own un-sharded
    run."""
    from repro.adversary.scenarios import run_stream_scenario

    flushes, shards = 30, 2
    finals = {}
    for agg in ("fedavg", "br_drag_trust"):
        finals[agg] = run_stream_scenario(
            Scenario(aggregator=agg, attack="buffer_flood", seed=0),
            flushes=flushes, shards=shards,
        )["final_loss"]
    assert np.isfinite(finals["br_drag_trust"])
    assert finals["br_drag_trust"] < finals["fedavg"], finals
    # sharding is a layout change, not a robustness change: the sharded
    # trust run stays within the BREAK_FACTOR envelope of the un-sharded
    unsharded = run_stream_scenario(
        Scenario(aggregator="br_drag_trust", attack="buffer_flood", seed=0),
        flushes=flushes,
    )["final_loss"]
    assert finals["br_drag_trust"] <= BREAK_FACTOR * max(unsharded, 1e-6), (
        finals, unsharded
    )


@pytest.mark.slow
def test_mini_scenario_matrix_acceptance():
    """Miniature sweep through the benchmark's own code path: the
    acceptance invariant (br_drag_trust < fedavg on final loss in every
    byzantine cell, sync and async) holds on the reduced grid."""
    import benchmarks.robustness_bench as bench

    cells = []
    for agg in ("fedavg", "br_drag_trust"):
        proto = Scenario(aggregator=agg, heterogeneity=1.0, rounds=30)
        baselines = {
            0: run_scenario(dataclasses.replace(proto, attack="none"))["final_loss"]
        }
        for attack, kw in bench.ATTACKS:
            cell = run_cell(
                dataclasses.replace(proto, attack=attack, attack_kw=kw),
                bench.BREAK_FACTOR, seeds=(0,), baselines=baselines,
            )
            cells.append(cell)
    acceptance = bench.check_acceptance(cells, [])
    assert acceptance["br_drag_trust_beats_fedavg"], acceptance["failures"]
