"""Interpret-mode sweeps of the flash-attention and selective-scan
Pallas kernels against the ref.py oracles (assignment deliverable (c):
per-kernel shape/dtype sweeps + allclose)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import io_bytes as attn_io_bytes
from repro.kernels.selective_scan import io_bytes as scan_io_bytes

jax.config.update("jax_platform_name", "cpu")


def _qkv(key, b, h, hkv, sq, sk, dh, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, h, sq, dh), dtype)
    k = jax.random.normal(k2, (b, hkv, sk, dh), dtype)
    v = jax.random.normal(k3, (b, hkv, sk, dh), dtype)
    return q, k, v


ATTN_CASES = [
    # b, h, hkv, sq, sk, dh, causal, window
    (1, 2, 2, 64, 64, 32, True, None),
    (2, 4, 2, 128, 128, 32, True, None),  # GQA 2:1
    (1, 8, 1, 64, 64, 16, True, None),  # MQA
    (1, 2, 2, 96, 96, 32, True, None),  # padding path (96 % 64 != 0)
    (1, 2, 1, 128, 128, 32, True, 48),  # sliding window
    (1, 2, 2, 64, 128, 32, True, None),  # cross Sq != Sk
    (2, 2, 2, 64, 64, 64, False, None),  # bidirectional (encoder)
]


@pytest.mark.parametrize("case", ATTN_CASES, ids=[str(c) for c in ATTN_CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    b, h, hkv, sq, sk, dh, causal, window = case
    q, k, v = _qkv(jax.random.PRNGKey(hash(case) % 2**31), b, h, hkv, sq, sk, dh, dtype)
    got = ops.flash_attention(
        q, k, v, causal=causal, window=window, block_q=64, block_k=64, interpret=True
    )
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_no_nan_on_fully_masked_rows():
    # window=1 + causal means row 0 attends only to itself; never NaN
    q, k, v = _qkv(jax.random.PRNGKey(0), 1, 2, 2, 64, 64, 32, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=1, block_q=64, block_k=64,
                              interpret=True)
    assert not bool(jnp.any(jnp.isnan(out)))


SCAN_CASES = [
    # b, s, di, ds, block_di, chunk
    (1, 64, 128, 8, 128, 32),
    (2, 128, 256, 16, 128, 64),
    (1, 96, 128, 4, 128, 96),  # chunk == s fallback
    (2, 64, 384, 16, 128, 16),  # di tiles = 3
]


@pytest.mark.parametrize("case", SCAN_CASES, ids=[str(c) for c in SCAN_CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_selective_scan_matches_ref(case, dtype):
    b, s, di, ds, bdi, ck = case
    key = jax.random.PRNGKey(hash(case) % 2**31)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jax.nn.softplus(jax.random.normal(k1, (b, s, di))).astype(dtype) * 0.1
    x = jax.random.normal(k2, (b, s, di), dtype)
    bm = jax.random.normal(k3, (b, s, ds), dtype)
    cm = jax.random.normal(k4, (b, s, ds), dtype)
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(7), (di, ds)) * 0.3)

    got = ops.selective_scan(dt, x, bm, cm, a, block_di=bdi, chunk=ck, interpret=True)
    want = ref.selective_scan_ref(dt, x, bm, cm, a)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_selective_scan_state_carries_across_chunks():
    """Same data scanned with different chunk sizes must agree exactly."""
    key = jax.random.PRNGKey(3)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    b, s, di, ds = 1, 128, 128, 8
    dt = jax.nn.softplus(jax.random.normal(k1, (b, s, di))) * 0.1
    x = jax.random.normal(k2, (b, s, di))
    bm = jax.random.normal(k3, (b, s, ds))
    cm = jax.random.normal(k4, (b, s, ds))
    a = -jnp.exp(jnp.zeros((di, ds)))
    y1 = ops.selective_scan(dt, x, bm, cm, a, block_di=128, chunk=32, interpret=True)
    y2 = ops.selective_scan(dt, x, bm, cm, a, block_di=128, chunk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)


def test_io_bytes_formulas():
    # sanity: analytic I/O is linear in S and independent of Sq*Sk / levels
    assert attn_io_bytes(1, 8, 2, 4096, 4096, 128) == 2 * (
        2 * 8 * 4096 * 128 + 2 * 2 * 4096 * 128
    )
    assert scan_io_bytes(1, 4096, 8192, 16) == 4 * (
        3 * 4096 * 8192 + 2 * 4096 * 16
    ) + 4 * 8192 * 16


LR_CASES = [
    (1, 64, 128, 128, 32),
    (2, 128, 256, 128, 64),
    (1, 96, 128, 128, 96),
]


@pytest.mark.parametrize("case", LR_CASES, ids=[str(c) for c in LR_CASES])
def test_linear_recurrence_matches_ref(case):
    b, s, w, bw, ck = case
    key = jax.random.PRNGKey(hash(case) % 2**31)
    k1, k2 = jax.random.split(key)
    a = jax.nn.sigmoid(jax.random.normal(k1, (b, s, w)))  # decay in (0,1)
    g = jax.random.normal(k2, (b, s, w)) * 0.5
    got = ops.linear_recurrence(a, g, block_w=bw, chunk=ck, interpret=True)
    want = ref.linear_recurrence_ref(a, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-5)


def test_linear_recurrence_chunk_invariance():
    key = jax.random.PRNGKey(11)
    k1, k2 = jax.random.split(key)
    a = jax.nn.sigmoid(jax.random.normal(k1, (1, 128, 128)))
    g = jax.random.normal(k2, (1, 128, 128))
    y1 = ops.linear_recurrence(a, g, block_w=128, chunk=32, interpret=True)
    y2 = ops.linear_recurrence(a, g, block_w=128, chunk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-6)
