"""The declarative experiment plane (``repro.api``): serialization
round trips, registry validation, lowering parity against the legacy
hand-rolled configs, and new-API-vs-legacy shim run parity.
"""
import dataclasses
import json

import pytest

from repro.api import (
    AggregationSpec,
    AsyncRegime,
    AttackSpec,
    DataSpec,
    ExperimentSpec,
    ModelSpec,
    ShardedRegime,
    SpecError,
    SyncRegime,
    TrustSpec,
    lowering,
    validate,
)
from repro.api import compile as api_compile


# ----------------------------------------------------------- serialization
class TestRoundTrip:
    def _assert_lossless(self, spec):
        d = spec.to_dict()
        assert ExperimentSpec.from_dict(d) == spec
        # through REAL JSON (tuples become lists on the wire)
        assert ExperimentSpec.from_dict(json.loads(json.dumps(d))) == spec
        assert ExperimentSpec.from_json(spec.to_json()) == spec

    def test_default_spec(self):
        self._assert_lossless(ExperimentSpec())

    def test_nested_attack_kwargs(self):
        spec = ExperimentSpec(
            attack=AttackSpec(
                "schedule", {"phases": ((0, "sign_flipping"), (20, "alie"))}
            ),
            trust=TrustSpec(True, {"decay": 0.9}),
            aggregation=AggregationSpec("br_drag"),
            regime=AsyncRegime(buffer_capacity=8, latency_kw={"scale": 2.0}),
        )
        self._assert_lossless(spec)
        # the nested phases survive as TUPLES (hashable once lowered)
        back = ExperimentSpec.from_json(spec.to_json())
        assert back.attack.kwargs["phases"] == ((0, "sign_flipping"), (20, "alie"))
        assert isinstance(back.attack.kwargs["phases"], tuple)

    def test_regime_tag_dispatch(self):
        for regime in (SyncRegime(rounds=7), AsyncRegime(flushes=3),
                       ShardedRegime(shards=4, buffer_capacity=8)):
            spec = ExperimentSpec(regime=regime)
            back = ExperimentSpec.from_json(spec.to_json())
            assert type(back.regime) is type(regime)
            assert back.regime == regime

    def test_unknown_regime_kind(self):
        with pytest.raises(ValueError, match="unknown regime kind"):
            ExperimentSpec.from_dict({"regime": {"kind": "quantum"}})

    def test_unknown_top_level_section(self):
        # a typo'd provenance record must fail loudly, not silently
        # reproduce a default experiment
        with pytest.raises(ValueError, match="unknown ExperimentSpec sections"):
            ExperimentSpec.from_dict({"agression": {"algorithm": "krum"}})

    def test_specs_are_hashable(self):
        # sweep-grid dedup: specs work as set members / cache keys
        a = ExperimentSpec(
            attack=AttackSpec("schedule",
                              {"phases": ((0, "sign_flipping"), (20, "alie"))}),
            regime=AsyncRegime(latency_kw={"scale": 2.0}),
        )
        b = ExperimentSpec.from_json(a.to_json())
        assert hash(a) == hash(b) and a == b
        assert len({a, b, ExperimentSpec()}) == 2

    def test_hypothesis_round_trip(self):
        hypothesis = pytest.importorskip("hypothesis")
        import hypothesis.strategies as st
        from hypothesis import given, settings

        scalars = st.one_of(
            st.integers(-100, 100),
            st.floats(-10, 10, allow_nan=False, allow_infinity=False),
            st.booleans(),
            st.text(max_size=8),
        )
        kwargs = st.dictionaries(
            st.text(min_size=1, max_size=8),
            st.one_of(scalars, st.lists(scalars, max_size=3).map(tuple)),
            max_size=3,
        )
        regimes = st.one_of(
            st.builds(SyncRegime, rounds=st.integers(1, 50),
                      n_selected=st.integers(1, 8)),
            st.builds(AsyncRegime, flushes=st.integers(1, 50),
                      buffer_capacity=st.integers(1, 32),
                      discount=st.sampled_from(["none", "poly", "exp"]),
                      latency_kw=kwargs),
            st.builds(ShardedRegime, shards=st.integers(1, 4),
                      buffer_capacity=st.integers(1, 32),
                      emulate=st.booleans()),
        )
        spec_st = st.builds(
            ExperimentSpec,
            data=st.builds(DataSpec, dataset=st.sampled_from(
                ["emnist", "cifar10", "scenario"]),
                n_workers=st.integers(1, 64),
                malicious_fraction=st.floats(0, 1, allow_nan=False)),
            model=st.builds(ModelSpec, name=st.sampled_from(["mlp", "quadratic"])),
            aggregation=st.builds(
                AggregationSpec,
                algorithm=st.sampled_from(["fedavg", "drag", "br_drag", "krum"]),
                n_byzantine_hint=st.one_of(st.none(), st.integers(0, 8)),
            ),
            attack=st.builds(AttackSpec, name=st.sampled_from(
                ["none", "alie", "ipm"]), kwargs=kwargs),
            trust=st.builds(TrustSpec, enabled=st.booleans(), kwargs=kwargs),
            regime=regimes,
            seed=st.integers(0, 1000),
        )

        @settings(max_examples=60, deadline=None)
        @given(spec=spec_st)
        def prop(spec):
            d = spec.to_dict()
            assert ExperimentSpec.from_dict(d) == spec
            assert ExperimentSpec.from_dict(json.loads(json.dumps(d))) == spec

        prop()

    def test_legacy_tuple_kwargs_deprecated(self):
        with pytest.warns(DeprecationWarning, match="tuple-of-pairs"):
            a = AttackSpec("ipm", (("eps", 2.0),))
        assert a == AttackSpec("ipm", {"eps": 2.0})
        with pytest.warns(DeprecationWarning):
            t = TrustSpec(True, (("decay", 0.7),))
        assert t.kwargs == {"decay": 0.7}
        # the empty tuple is the legacy no-op default: no warning
        import warnings as _w

        with _w.catch_warnings():
            _w.simplefilter("error")
            assert AttackSpec("none", ()).kwargs == {}
        # a flattened (malformed) pair tuple fails with a clear message
        with pytest.raises(TypeError, match="tuple of \\(key, value\\) pairs"):
            AttackSpec("ipm", ("eps", 2.0))


# ------------------------------------------------------------- validation
class TestValidation:
    def test_unknown_attack(self):
        with pytest.raises(SpecError, match="unknown attack 'bogus'"):
            validate(ExperimentSpec(attack=AttackSpec("bogus")))

    def test_attack_rejects_bad_kwargs(self):
        # empty phases is a construction-time error in the registry
        with pytest.raises(SpecError, match="rejects kwargs"):
            validate(ExperimentSpec(attack=AttackSpec("schedule", {"phases": ()})))
        # an unknown inner attack of a combinator fails resolution
        with pytest.raises(SpecError, match="rejects kwargs"):
            validate(ExperimentSpec(attack=AttackSpec("ramp", {"inner": "bogus"})))

    def test_unknown_sync_algorithm(self):
        with pytest.raises(SpecError, match="unknown sync algorithm"):
            validate(ExperimentSpec(aggregation=AggregationSpec("magic_mean")))

    def test_client_variant_rule_off_flat_plane(self):
        # scaffold exists in the sync tier but cannot stream
        with pytest.raises(SpecError, match="client-variant"):
            validate(ExperimentSpec(
                aggregation=AggregationSpec("scaffold"), regime=AsyncRegime()
            ))

    def test_non_flat_capable_on_flat_plane(self):
        with pytest.raises(SpecError, match="not FLAT_CAPABLE"):
            validate(ExperimentSpec(
                aggregation=AggregationSpec("magic_mean"), regime=AsyncRegime()
            ))

    def test_sharded_needs_flat_twin_with_hierarchical_flush(self):
        with pytest.raises(SpecError, match="one-psum"):
            validate(ExperimentSpec(
                aggregation=AggregationSpec("median"),
                regime=ShardedRegime(shards=2, buffer_capacity=8),
            ))

    def test_sharded_capacity_divisibility(self):
        with pytest.raises(SpecError, match="divide"):
            validate(ExperimentSpec(
                aggregation=AggregationSpec("drag"),
                regime=ShardedRegime(shards=3, buffer_capacity=8),
            ))

    def test_sharded_without_mesh(self):
        with pytest.raises(SpecError, match="pod mesh"):
            validate(ExperimentSpec(
                aggregation=AggregationSpec("drag"),
                regime=ShardedRegime(shards=2, buffer_capacity=8, emulate=False),
            ))
        # emulation opt-in passes on one device
        validate(ExperimentSpec(
            aggregation=AggregationSpec("drag"),
            regime=ShardedRegime(shards=2, buffer_capacity=8, emulate=True),
        ))

    def test_sharded_mesh_axis_mismatch(self):
        from repro.launch.mesh import make_pod_mesh

        mesh = make_pod_mesh(1)
        with pytest.raises(SpecError, match="'pod'"):
            validate(ExperimentSpec(
                aggregation=AggregationSpec("drag"),
                regime=ShardedRegime(shards=2, buffer_capacity=8),
            ), mesh=mesh)
        validate(ExperimentSpec(
            aggregation=AggregationSpec("drag"),
            regime=ShardedRegime(shards=1, buffer_capacity=8),
        ), mesh=mesh)

    def test_trust_needs_reference_direction(self):
        with pytest.raises(SpecError, match="reference direction"):
            validate(ExperimentSpec(
                aggregation=AggregationSpec("fedavg"), trust=TrustSpec(True)
            ))

    def test_unknown_trust_field(self):
        with pytest.raises(SpecError, match="TrustConfig"):
            validate(ExperimentSpec(
                aggregation=AggregationSpec("drag"),
                trust=TrustSpec(True, {"vibes": 1.0}),
            ))

    def test_unknown_dataset_model_latency(self):
        with pytest.raises(SpecError, match="unknown dataset"):
            validate(ExperimentSpec(data=DataSpec(dataset="imagenet")))
        with pytest.raises(SpecError, match="unknown model"):
            validate(ExperimentSpec(model=ModelSpec("resnet152")))
        with pytest.raises(SpecError, match="unknown latency"):
            validate(ExperimentSpec(regime=AsyncRegime(latency="psychic")))

    def test_n_selected_bounds(self):
        with pytest.raises(SpecError, match="n_selected"):
            validate(ExperimentSpec(
                data=DataSpec(n_workers=4), regime=SyncRegime(n_selected=10)
            ))

    def test_positivity_bounds(self):
        with pytest.raises(SpecError, match="eval_every"):
            validate(ExperimentSpec(regime=SyncRegime(eval_every=0)))
        with pytest.raises(SpecError, match="rounds"):
            validate(ExperimentSpec(regime=SyncRegime(rounds=0)))
        with pytest.raises(SpecError, match="concurrency"):
            validate(ExperimentSpec(regime=AsyncRegime(concurrency=0)))
        with pytest.raises(SpecError, match="flushes"):
            validate(ExperimentSpec(regime=AsyncRegime(flushes=0)))

    def test_latency_kwarg_typo_is_caught(self):
        # the latency factories swallow **kw, so this typo would
        # otherwise run silently with the default scale
        with pytest.raises(SpecError, match="no kwargs"):
            validate(ExperimentSpec(
                regime=AsyncRegime(latency="exponential",
                                   latency_kw={"scael": 2.0})
            ))
        validate(ExperimentSpec(
            regime=AsyncRegime(latency="exponential", latency_kw={"scale": 2.0})
        ))


# ------------------------------------------------- lowering parity (oracle)
class TestLoweringParity:
    def test_round_config_matches_legacy_hand_roll(self):
        from repro.fl.round import RoundConfig
        from repro.fl.server import ExperimentConfig

        exp = ExperimentConfig(
            algorithm="br_drag", attack="alie", attack_kw=(("z", 1.2),),
            malicious_fraction=0.4, n_selected=10, trust=True,
            trust_kw=(("decay", 0.9),), local_steps=3, lr=0.05,
        )
        cfg = lowering.round_config(exp.to_spec())
        # field-for-field what fl/server.py used to hand-roll
        assert cfg == RoundConfig(
            algorithm="br_drag", local_steps=3, lr=0.05, alpha=exp.alpha,
            c=exp.c, c_br=exp.c_br, attack="alie", attack_kw=(("z", 1.2),),
            n_byzantine_hint=4, trust=True, trust_kw=(("decay", 0.9),),
        )

    def test_benign_hint_is_zero(self):
        spec = ExperimentSpec(aggregation=AggregationSpec("krum"))
        assert lowering.round_config(spec).n_byzantine_hint == 0

    def test_stream_config_matches_legacy_hand_roll(self):
        from repro.stream.server import StreamConfig, StreamExperimentConfig

        exp = StreamExperimentConfig(
            algorithm="br_drag", attack="ipm", attack_kw=(("eps", 2.0),),
            malicious_fraction=0.4, buffer_capacity=8, discount="exp",
            discount_a=0.7, trust=True, root_refresh_every=3, shards=2,
        )
        cfg = lowering.stream_config(exp.to_spec())
        assert cfg == StreamConfig(
            algorithm="br_drag", buffer_capacity=8, local_steps=exp.local_steps,
            lr=exp.lr, alpha=exp.alpha, c=exp.c, c_br=exp.c_br, discount="exp",
            discount_a=0.7, attack="ipm", attack_kw=(("eps", 2.0),),
            n_byzantine_hint=3, trust=True, root_refresh_every=3, shards=2,
        )

    def test_bridge_lowering_is_the_old_conversion(self):
        from repro.fl import bridge
        from repro.fl.round import RoundConfig
        from repro.stream.server import StreamConfig

        rc = RoundConfig(
            algorithm="drag", attack="sign_flipping", attack_kw=(("scale", 2.0),),
            n_byzantine_hint=2, trust=True, trust_kw=(("decay", 0.8),),
        )
        cfg = bridge.stream_config_from_round(rc, capacity=6, shards=2)
        assert cfg == StreamConfig(
            shards=2, algorithm="drag", buffer_capacity=6,
            local_steps=rc.local_steps, lr=rc.lr, alpha=rc.alpha, c=rc.c,
            c_br=rc.c_br, discount="none", attack="sign_flipping",
            attack_kw=(("scale", 2.0),), n_byzantine_hint=2,
            geomed_iters=rc.geomed_iters, trust=True,
            trust_kw=(("decay", 0.8),),
        )

    def test_scenario_stream_lowering_matches_hand_roll(self):
        from repro.adversary.scenarios import Scenario, stream_spec
        from repro.stream.server import StreamConfig

        sc = Scenario(aggregator="br_drag_trust", attack="buffer_flood",
                      trust_kw=(("decay", 0.85),))
        cfg = lowering.stream_config(stream_spec(sc, buffer_capacity=8, shards=2))
        assert cfg == StreamConfig(
            algorithm="br_drag", buffer_capacity=8, local_steps=sc.local_steps,
            lr=sc.lr, alpha=sc.alpha, c=sc.c, c_br=sc.c_br, discount="poly",
            discount_a=0.5, attack="buffer_flood", attack_kw=(),
            n_byzantine_hint=3, trust=True, trust_kw=(("decay", 0.85),),
            shards=2,
        )

    def test_as_spec_rejects_garbage(self):
        with pytest.raises(TypeError, match="ExperimentSpec"):
            lowering.as_spec({"algorithm": "fedavg"})


# --------------------------------------------------------- shim run parity
def _tiny_sync_kw():
    return dict(
        dataset="emnist", model="mlp", n_workers=6, n_selected=3, rounds=2,
        local_steps=1, batch_size=4, eval_every=1, seed=3,
    )


class TestShimParity:
    def test_sync_legacy_equals_new_api(self):
        from repro.fl.server import ExperimentConfig, run_experiment

        exp = ExperimentConfig(algorithm="drag", **_tiny_sync_kw())
        h_legacy = run_experiment(exp)

        spec = ExperimentSpec(
            data=DataSpec(dataset="emnist", n_workers=6),
            model=ModelSpec("mlp"),
            aggregation=AggregationSpec("drag"),
            regime=SyncRegime(rounds=2, n_selected=3, local_steps=1,
                              batch_size=4, eval_every=1),
            seed=3,
        )
        h_api = api_compile(spec).run()
        assert h_api["accuracy"] == h_legacy["accuracy"]
        assert h_api["update_norm"] == h_legacy["update_norm"]

    def test_async_legacy_equals_new_api(self):
        from repro.stream.server import StreamExperimentConfig, run_stream_experiment

        exp = StreamExperimentConfig(
            dataset="emnist", model="mlp", n_workers=6, concurrency=4,
            flushes=2, buffer_capacity=3, local_steps=1, batch_size=4,
            algorithm="drag", discount="poly", eval_every=1, seed=3,
        )
        h_legacy = run_stream_experiment(exp)

        spec = ExperimentSpec(
            data=DataSpec(dataset="emnist", n_workers=6),
            model=ModelSpec("mlp"),
            aggregation=AggregationSpec("drag"),
            regime=AsyncRegime(flushes=2, concurrency=4, buffer_capacity=3,
                               local_steps=1, batch_size=4, discount="poly",
                               eval_every=1),
            seed=3,
        )
        h_api = api_compile(spec).run()
        assert h_api["accuracy"] == h_legacy["accuracy"]
        assert h_api["staleness_mean"] == h_legacy["staleness_mean"]

    def test_regime_engine_mismatch_is_actionable(self):
        from repro.fl.server import run_experiment
        from repro.stream.server import run_stream_experiment

        with pytest.raises(ValueError, match="synchronous"):
            run_experiment(ExperimentSpec(regime=AsyncRegime()))
        with pytest.raises(ValueError, match="async"):
            run_stream_experiment(ExperimentSpec(regime=SyncRegime()))

    def test_compile_validates(self):
        with pytest.raises(SpecError):
            api_compile(ExperimentSpec(attack=AttackSpec("bogus")))

    def test_scenario_lab_specs_are_not_engine_executable(self):
        # the lab validates (spec-matrix) but has no engine behind it:
        # compile/run must fail actionably, not with a pipeline KeyError
        from repro.adversary.scenarios import Scenario, stream_spec, sync_spec

        validate(sync_spec(Scenario()))
        with pytest.raises(SpecError, match="scenario"):
            api_compile(sync_spec(Scenario()))
        with pytest.raises(SpecError, match="scenario"):
            from repro.stream.server import run_stream_experiment

            run_stream_experiment(stream_spec(Scenario()))

    def test_compile_forwards_mesh_to_sharded_run(self):
        from repro.launch.mesh import make_pod_mesh

        mesh = make_pod_mesh(1)
        spec = ExperimentSpec(
            data=DataSpec(dataset="emnist", n_workers=6),
            model=ModelSpec("mlp"),
            aggregation=AggregationSpec("drag"),
            regime=ShardedRegime(shards=1, flushes=2, concurrency=4,
                                 buffer_capacity=2, local_steps=1,
                                 batch_size=4, eval_every=1),
            seed=3,
        )
        compiled = api_compile(spec, mesh=mesh)
        assert compiled.mesh is mesh
        h = compiled.run()  # the validated mesh drives the sharded engine
        assert h["final_accuracy"] >= 0.0


# -------------------------------------------------------- spec-matrix gate
class TestSpecMatrix:
    def test_all_declared_specs_validate(self):
        from benchmarks.spec_matrix import check, collect

        specs = collect()
        assert len(specs) > 100  # the full matrix, not a stub
        assert check(specs) == []
