"""Baseline aggregator unit tests (paper §VI benchmark algorithms)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregators as agg
from repro.core import pytree as pt


def _ups(key, s=10):
    k1, k2 = jax.random.split(key)
    return {
        "w": jax.random.normal(k1, (s, 6, 4)),
        "b": jax.random.normal(k2, (s, 3)),
    }


def test_fedavg_is_mean():
    ups = _ups(jax.random.PRNGKey(0))
    out = agg.fedavg(ups)
    np.testing.assert_allclose(out["w"], jnp.mean(ups["w"], 0), rtol=1e-6)


def test_fedexp_at_least_mean():
    """eta_g >= 1 always (max with 1)."""
    ups = _ups(jax.random.PRNGKey(1))
    mean = agg.fedavg(ups)
    out = agg.fedexp(ups)
    assert float(pt.tree_norm(out)) >= float(pt.tree_norm(mean)) - 1e-6


def test_fedexp_identical_updates_eta_one_half_s():
    """With identical updates, sum||g||^2 / (2S||mean||^2) = 1/2 -> eta=1."""
    g = {"w": jnp.ones((4, 5))}
    out = agg.fedexp(g, eps=0.0)
    np.testing.assert_allclose(out["w"], jnp.ones(5), rtol=1e-5)


def test_fltrust_clips_negative_cosine():
    """Updates opposing r get zero trust weight."""
    r = {"w": jnp.ones((1, 8))[0]}
    ups = {"w": jnp.stack([jnp.ones(8), -jnp.ones(8)])}
    out = agg.fltrust(ups, r)
    # only the aligned worker contributes, scaled to ||r||
    np.testing.assert_allclose(out["w"], jnp.ones(8), rtol=1e-5)


def test_fltrust_norm_matching():
    """Each trusted update is rescaled to ||r|| (FLTrust [29])."""
    r = {"w": jnp.array([1.0, 0.0])}
    ups = {"w": jnp.array([[1000.0, 0.0]])}
    out = agg.fltrust(ups, r)
    np.testing.assert_allclose(out["w"], jnp.array([1.0, 0.0]), rtol=1e-5)


def test_geometric_median_outlier_resistance():
    key = jax.random.PRNGKey(2)
    ups = {"w": jax.random.normal(key, (10, 32)) * 0.1}
    ups["w"] = ups["w"].at[0].set(1e4)
    gm = agg.geometric_median(ups, iters=16)
    assert float(pt.tree_norm(gm)) < 1.0


def test_krum_selects_inlier():
    key = jax.random.PRNGKey(3)
    base = jax.random.normal(key, (12,))
    ups = {"w": base[None] + 0.01 * jax.random.normal(key, (8, 12))}
    ups["w"] = ups["w"].at[0].set(100.0)  # Byzantine
    out = agg.krum(ups, n_byzantine=1)
    assert float(jnp.linalg.norm(out["w"] - base)) < 1.0


def test_trimmed_mean_beats_mean_under_outliers():
    key = jax.random.PRNGKey(4)
    ups = {"w": jax.random.normal(key, (10, 16)) * 0.1}
    ups["w"] = ups["w"].at[0].set(50.0).at[1].set(-80.0)
    tm = agg.trimmed_mean(ups, trim=2)
    mean = agg.fedavg(ups)
    assert float(pt.tree_norm(tm)) < float(pt.tree_norm(mean))


def test_coordinate_median():
    ups = {"w": jnp.array([[1.0], [2.0], [100.0]])}
    np.testing.assert_allclose(agg.coordinate_median(ups)["w"], [2.0])


def test_registry_complete():
    for name in ["fedavg", "fedexp", "fltrust", "geomed", "rfa", "raga",
                 "krum", "trimmed_mean", "median", "drag", "br_drag"]:
        assert name in agg.AGGREGATORS
    with pytest.raises(KeyError):
        agg.get("nope")


def test_jit_compatible():
    ups = _ups(jax.random.PRNGKey(5))
    r = pt.tree_index(ups, 0)
    jax.jit(agg.fedavg)(ups)
    jax.jit(agg.fedexp)(ups)
    jax.jit(agg.fltrust)(ups, r)
    jax.jit(lambda u: agg.geometric_median(u, iters=4))(ups)
    jax.jit(lambda u: agg.krum(u, 2))(ups)
    jax.jit(lambda u: agg.trimmed_mean(u, 2))(ups)


def test_multi_krum_averages_inliers():
    """With one far outlier, multi-krum's output stays near the inlier mean."""
    key = jax.random.PRNGKey(5)
    ups = _ups(key, s=8)
    # worker 0 is a large outlier
    ups = jax.tree.map(lambda x: x.at[0].set(x[0] + 100.0), ups)
    out = agg.multi_krum(ups, n_byzantine=1)
    inlier_mean = jax.tree.map(lambda x: jnp.mean(x[1:], 0), ups)
    # closer to the inlier mean than to the poisoned mean
    d_in = float(pt.tree_norm(pt.tree_sub(out, inlier_mean)))
    d_all = float(pt.tree_norm(pt.tree_sub(out, agg.fedavg(ups))))
    assert d_in < d_all


def test_bulyan_outlier_resistance():
    key = jax.random.PRNGKey(6)
    ups = _ups(key, s=12)
    ups = jax.tree.map(lambda x: x.at[0].set(x[0] * 0 + 50.0), ups)
    ups = jax.tree.map(lambda x: x.at[1].set(x[1] * 0 - 50.0), ups)
    out = agg.bulyan(ups, n_byzantine=2)
    # output magnitude bounded by the inlier scale, not the +-50 attackers
    assert float(pt.tree_norm(out)) < 10.0


def test_round_dispatch_registry_parity():
    """Every non-reference rule in AGGREGATORS must be reachable via
    RoundConfig.algorithm through the synchronous federated_round."""
    from repro.fl.round import RoundConfig, federated_round, init_server_state

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    s, u, b, d = 6, 2, 4, 3
    key = jax.random.PRNGKey(0)
    params = {"w": jnp.zeros((d, 1))}
    batches = {
        "x": jax.random.normal(key, (s, u, b, d)),
        "y": jax.random.normal(jax.random.fold_in(key, 1), (s, u, b, 1)),
    }
    mask = jnp.zeros((s,), bool)
    idx = jnp.arange(s, dtype=jnp.int32)
    for rule in sorted(set(agg.AGGREGATORS) - agg.NEEDS_REFERENCE):
        cfg = RoundConfig(algorithm=rule, local_steps=u, n_byzantine_hint=1)
        state = init_server_state(params, s)
        new_state, _ = federated_round(loss_fn, state, cfg, batches, idx, mask, key)
        moved = float(pt.tree_norm(pt.tree_sub(new_state.params, params)))
        assert np.isfinite(moved) and moved > 0, rule


def test_multi_krum_equals_krum_when_m_1():
    ups = _ups(jax.random.PRNGKey(7), s=6)
    out1 = agg.krum(ups, n_byzantine=1)
    out2 = agg.multi_krum(ups, n_byzantine=1, m=1)
    np.testing.assert_allclose(out1["w"], out2["w"], rtol=1e-6)
