"""Unit tests for the paper's core math (DRAG, §III)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import drag
from repro.core import pytree as pt


def _rand_tree(key, s=None):
    k1, k2 = jax.random.split(key)
    shape = lambda *t: ((s,) + t) if s else t
    return {
        "w": jax.random.normal(k1, shape(12, 7)),
        "b": jax.random.normal(k2, shape(5,)),
    }


class TestDoD:
    def test_range(self):
        """lambda in [0, 2c] (eq. 10)."""
        key = jax.random.PRNGKey(0)
        for c in (0.1, 0.5, 1.0):
            for i in range(20):
                g = _rand_tree(jax.random.fold_in(key, i))
                r = _rand_tree(jax.random.fold_in(key, 100 + i))
                lam = float(drag.degree_of_divergence(g, r, c))
                assert -1e-6 <= lam <= 2 * c + 1e-6

    def test_aligned_zero(self):
        g = _rand_tree(jax.random.PRNGKey(1))
        lam = float(drag.degree_of_divergence(g, pt.tree_scale(g, 3.0), 0.5))
        assert abs(lam) < 1e-5

    def test_opposed_max(self):
        g = _rand_tree(jax.random.PRNGKey(2))
        lam = float(drag.degree_of_divergence(g, pt.tree_scale(g, -2.0), 0.5))
        assert abs(lam - 1.0) < 1e-5


class TestCalibrate:
    def test_eq11_identity_when_aligned(self):
        """Aligned g (lam=0) passes through unchanged."""
        g = _rand_tree(jax.random.PRNGKey(3))
        v = drag.calibrate(g, pt.tree_scale(g, 2.0), 0.0)
        np.testing.assert_allclose(
            pt.tree_flatten_vector(v), pt.tree_flatten_vector(g), rtol=1e-6
        )

    def test_aligned_component_never_shrinks(self):
        """Fig. 2: <v, r>/||r|| >= <g, r>/||r|| for lam in [0, 2c]."""
        key = jax.random.PRNGKey(4)
        for i in range(30):
            g = _rand_tree(jax.random.fold_in(key, i))
            r = _rand_tree(jax.random.fold_in(key, 1000 + i))
            lam = drag.degree_of_divergence(g, r, 0.5)
            v = drag.calibrate(g, r, lam)
            rn = pt.tree_norm(r)
            assert float(pt.tree_dot(v, r) / rn) >= float(pt.tree_dot(g, r) / rn) - 1e-4

    def test_norm_preserving_structure(self):
        """v = (1-lam) g + lam (||g||/||r||) r: both terms scale with ||g||."""
        g = _rand_tree(jax.random.PRNGKey(5))
        r = _rand_tree(jax.random.PRNGKey(6))
        lam = drag.degree_of_divergence(g, r, 0.3)
        v1 = drag.calibrate(g, r, lam)
        v2 = drag.calibrate(pt.tree_scale(g, 2.0), r, lam)
        np.testing.assert_allclose(
            pt.tree_flatten_vector(v2), 2.0 * pt.tree_flatten_vector(v1), rtol=1e-5
        )


class TestReference:
    def test_bootstrap_then_ema(self):
        """r^0 = raw mean (5a); r^t = (1-a) r^{t-1} + a Delta (5b)."""
        key = jax.random.PRNGKey(7)
        params = _rand_tree(key)
        ups = _rand_tree(jax.random.fold_in(key, 1), s=6)
        state = drag.init_state(params)
        p1, st1, _ = drag.round_step(params, state, ups, alpha=0.25, c=0.1)
        raw_mean = jax.tree.map(lambda x: jnp.mean(x, 0), ups)
        np.testing.assert_allclose(
            pt.tree_flatten_vector(st1.reference),
            pt.tree_flatten_vector(raw_mean),
            rtol=1e-6,
        )
        # round 0 applies the raw mean (no calibration yet)
        np.testing.assert_allclose(
            pt.tree_flatten_vector(p1),
            pt.tree_flatten_vector(pt.tree_add(params, raw_mean)),
            rtol=1e-6,
        )
        # round 1: EMA update
        p2, st2, _ = drag.round_step(p1, st1, ups, alpha=0.25, c=0.1)
        delta, _ = drag.aggregate(ups, st1.reference, 0.1)
        expect = pt.tree_lincomb(0.75, st1.reference, 0.25, delta)
        np.testing.assert_allclose(
            pt.tree_flatten_vector(st2.reference),
            pt.tree_flatten_vector(expect),
            rtol=1e-5,
        )

    def test_closed_form_eq8(self):
        """r^t matches the closed-form EMA expansion (eq. 8)."""
        key = jax.random.PRNGKey(8)
        params = _rand_tree(key)
        state = drag.init_state(params)
        alpha, c = 0.3, 0.2
        p = params
        deltas = []
        r0 = None
        for t in range(4):
            ups = _rand_tree(jax.random.fold_in(key, 50 + t), s=5)
            p_new, state_new, _ = drag.round_step(p, state, ups, alpha=alpha, c=c)
            delta = pt.tree_sub(p_new, p)
            if t == 0:
                r0 = state_new.reference
            else:
                deltas.append(delta)
            p, state = p_new, state_new
        # closed form after T=4 rounds (deltas from rounds 1..3)
        tmax = len(deltas)
        expect = pt.tree_scale(r0, (1 - alpha) ** tmax)
        for i, d in enumerate(deltas):
            expect = pt.tree_axpy(alpha * (1 - alpha) ** (tmax - i - 1), d, expect)
        np.testing.assert_allclose(
            pt.tree_flatten_vector(state.reference),
            pt.tree_flatten_vector(expect),
            rtol=1e-4,
        )


def test_severe_divergence_reverses_gradient():
    """For lam > 1 (Fig. 2b) the g component flips sign."""
    g = {"w": jnp.array([1.0, 0.0])}
    r = {"w": jnp.array([-1.0, 0.0])}
    lam = drag.degree_of_divergence(g, r, 1.0)  # cos=-1 -> lam=2
    assert float(lam) == pytest.approx(2.0, abs=1e-5)
    v = drag.calibrate(g, r, lam)
    # v = (1-2) g + 2 * (1/1) r = -g + 2r = [-3, 0]
    np.testing.assert_allclose(v["w"], jnp.array([-3.0, 0.0]), atol=1e-5)
