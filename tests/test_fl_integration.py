"""End-to-end FL integration tests: the paper's protocol at reduced scale.

These mirror the §VI experiments qualitatively: DRAG should converge at
least as well as FedAvg under strong heterogeneity, and BR-DRAG must
stay standing under attacks that break plain averaging.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import build_federated_data
from repro.fl import ExperimentConfig, run_experiment


def _run(alg, rounds=20, attack="none", mal=0.0, model="mlp", dataset="emnist", **kw):
    exp = ExperimentConfig(
        dataset=dataset,
        model=model,
        rounds=rounds,
        beta=0.1,
        algorithm=alg,
        attack=attack,
        malicious_fraction=mal,
        eval_every=rounds,
        n_workers=20,
        n_selected=8,
        seed=3,
        **kw,
    )
    return run_experiment(exp)


class TestBenign:
    def test_fedavg_learns(self):
        h = _run("fedavg", rounds=25)
        assert h["final_accuracy"] > 0.10  # well above 1/47 chance

    def test_drag_learns_at_least_as_well(self):
        h_avg = _run("fedavg", rounds=25)
        h_drag = _run("drag", rounds=25, c=0.25)
        assert h_drag["final_accuracy"] >= 0.8 * h_avg["final_accuracy"]

    @pytest.mark.parametrize("alg", ["fedprox", "scaffold", "fedexp", "fedacg"])
    def test_baselines_run(self, alg):
        h = _run(alg, rounds=8)
        assert np.isfinite(h["final_accuracy"])
        assert h["final_accuracy"] > 0.02


class TestByzantine:
    @pytest.mark.parametrize("attack", ["sign_flipping", "noise_injection"])
    def test_br_drag_survives_60pct(self, attack):
        """60% malicious: BR-DRAG must stay above chance-ish accuracy and
        beat FedAvg (paper Figs. 15-17)."""
        h_avg = _run("fedavg", rounds=20, attack=attack, mal=0.6)
        h_br = _run("br_drag", rounds=20, attack=attack, mal=0.6)
        assert h_br["final_accuracy"] >= h_avg["final_accuracy"] - 0.02
        assert h_br["final_accuracy"] > 0.08

    def test_label_flipping_brdrag(self):
        h_br = _run("br_drag", rounds=15, attack="label_flipping", mal=0.3)
        assert h_br["final_accuracy"] > 0.08

    @pytest.mark.parametrize("alg", ["fltrust", "rfa", "raga"])
    def test_defense_baselines_run_under_attack(self, alg):
        h = _run(alg, rounds=8, attack="sign_flipping", mal=0.3)
        assert np.isfinite(h["final_accuracy"])


class TestProtocol:
    def test_partial_participation_selection(self):
        """Each round selects exactly S of M without replacement."""
        data = build_federated_data("emnist", 20, 0.5, seed=0)
        rng = np.random.RandomState(0)
        sel = rng.choice(20, size=8, replace=False)
        assert len(set(sel.tolist())) == 8
        batch = data.sample_round(rng, sel, u=5, b=4)
        assert batch["x"].shape == (8, 5, 4, 28, 28, 1)
        assert batch["y"].shape == (8, 5, 4)

    def test_root_dataset_from_benign_workers(self):
        data = build_federated_data(
            "emnist", 20, 0.5, malicious_fraction=0.5, attack="label_flipping", seed=0
        )
        rng = np.random.RandomState(1)
        root = data.root_batches(rng, u=3, b=4, n_root=100)
        assert root["x"].shape == (3, 4, 28, 28, 1)
        # all root indices come from benign workers' partitions
        benign_pool = set(
            np.concatenate([data.parts[m] for m in np.where(~data.malicious)[0]]).tolist()
        )
        assert len(benign_pool) > 0

    def test_label_flipping_poisons_malicious_samples(self):
        data = build_federated_data(
            "emnist", 10, 0.5, malicious_fraction=0.5, attack="label_flipping", seed=0
        )
        rng = np.random.RandomState(2)
        mal = np.where(data.malicious)[0]
        batch = data.sample_round(rng, mal[:2], u=1, b=64)
        # ~half of labels should differ from the clean labels
        clean = data.y[np.concatenate([data.parts[m] for m in mal[:2]])]
        frac_extreme = np.mean(batch["y"] != np.clip(batch["y"], 0, 46))
        assert batch["y"].min() >= 0 and batch["y"].max() <= 46
