"""Hypothesis property-based tests on the system's invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import aggregators, attacks, br_drag, drag
from repro.core import pytree as pt
from repro.data.dirichlet import dirichlet_partition

jax.config.update("jax_platform_name", "cpu")

# allow_subnormal=False: XLA:CPU flushes subnormals to zero, so exact
# involution/scale properties only hold over normal floats.
vec = hnp.arrays(
    np.float32,
    st.integers(4, 48),
    elements=st.floats(-100, 100, width=32, allow_nan=False, allow_subnormal=False),
)

mat = hnp.arrays(
    np.float32,
    st.tuples(st.integers(2, 12), st.integers(4, 32)),
    elements=st.floats(-50, 50, width=32, allow_nan=False, allow_subnormal=False),
)


def _nonzero(x, eps=1e-3):
    return float(np.linalg.norm(x)) > eps


@settings(max_examples=40, deadline=None)
@given(g=vec, scale=st.floats(0.1, 10.0))
def test_dod_scale_invariant(g, scale):
    """lambda depends only on direction: lambda(a g, r) == lambda(g, r)."""
    hypothesis.assume(_nonzero(g))
    r = np.roll(g, 1) + 1.0
    hypothesis.assume(_nonzero(r))
    l1 = float(drag.degree_of_divergence({"w": jnp.asarray(g)}, {"w": jnp.asarray(r)}, 0.5))
    l2 = float(
        drag.degree_of_divergence({"w": jnp.asarray(g * scale)}, {"w": jnp.asarray(r)}, 0.5)
    )
    assert abs(l1 - l2) < 1e-3


@settings(max_examples=40, deadline=None)
@given(g=vec, c=st.floats(0.01, 1.0))
def test_dod_bounds(g, c):
    hypothesis.assume(_nonzero(g))
    r = np.roll(g, 3) - 0.5
    hypothesis.assume(_nonzero(r))
    lam = float(drag.degree_of_divergence({"w": jnp.asarray(g)}, {"w": jnp.asarray(r)}, c))
    assert -1e-5 <= lam <= 2 * c + 1e-5


@settings(max_examples=40, deadline=None)
@given(g=vec)
def test_br_drag_norm_never_exceeds_reference(g):
    """The Appendix-B bound ||v|| <= ||r|| holds for arbitrary updates."""
    hypothesis.assume(_nonzero(g))
    r = np.roll(g, 2) + 0.25
    hypothesis.assume(_nonzero(r))
    gt, rt = {"w": jnp.asarray(g)}, {"w": jnp.asarray(r)}
    lam = drag.degree_of_divergence(gt, rt, 0.5)
    v = br_drag.calibrate(gt, rt, lam)
    assert float(pt.tree_norm(v)) <= float(pt.tree_norm(rt)) * (1 + 1e-3) + 1e-4


@settings(max_examples=40, deadline=None)
@given(g=vec)
def test_drag_aligned_component_monotone(g):
    """<v, r> >= <g, r> after calibration (drift reduction, Fig. 2)."""
    hypothesis.assume(_nonzero(g))
    r = np.roll(g, 1) * 0.5 + 0.1
    hypothesis.assume(_nonzero(r))
    gt, rt = {"w": jnp.asarray(g)}, {"w": jnp.asarray(r)}
    lam = drag.degree_of_divergence(gt, rt, 0.5)
    v = drag.calibrate(gt, rt, lam)
    assert float(pt.tree_dot(v, rt)) >= float(pt.tree_dot(gt, rt)) - 1e-2 * (
        1 + abs(float(pt.tree_dot(gt, rt)))
    )


@settings(max_examples=30, deadline=None)
@given(m=mat)
def test_geomed_within_convex_hull_norm(m):
    """||GeoMed|| <= max_s ||g_s|| (it is a convex combination)."""
    hypothesis.assume(all(_nonzero(row) for row in m))
    ups = {"w": jnp.asarray(m)}
    z = aggregators.geometric_median(ups, iters=8)
    assert float(pt.tree_norm(z)) <= float(np.max(np.linalg.norm(m, axis=1))) * (1 + 1e-3)


@settings(max_examples=30, deadline=None)
@given(m=mat, trim=st.integers(1, 3))
def test_trimmed_mean_within_range(m, trim):
    hypothesis.assume(m.shape[0] > 2 * trim)
    ups = {"w": jnp.asarray(m)}
    out = np.asarray(aggregators.trimmed_mean(ups, trim)["w"])
    assert (out <= m.max(axis=0) + 1e-5).all()
    assert (out >= m.min(axis=0) - 1e-5).all()


@settings(max_examples=30, deadline=None)
@given(
    labels=hnp.arrays(np.int64, st.integers(50, 300), elements=st.integers(0, 9)),
    n_workers=st.integers(2, 10),
    beta=st.floats(0.05, 5.0),
)
def test_dirichlet_partition_is_a_partition(labels, n_workers, beta):
    """Every sample assigned at least once; per-worker sets non-empty."""
    parts = dirichlet_partition(labels, n_workers, beta, seed=0)
    assert len(parts) == n_workers
    for p in parts:
        assert len(p) >= 1
    covered = np.concatenate(parts)
    assert set(covered.tolist()) >= set(range(len(labels))) - set(covered.tolist()) or len(
        np.unique(covered)
    ) <= len(labels)
    # indices valid
    assert covered.min() >= 0 and covered.max() < len(labels)


@settings(max_examples=20, deadline=None)
@given(m=mat)
def test_sign_flip_is_involution(m):
    ups = {"w": jnp.asarray(m)}
    mask = jnp.ones(m.shape[0], bool)
    k = jax.random.PRNGKey(0)
    twice = attacks.sign_flipping(k, attacks.sign_flipping(k, ups, mask), mask)
    np.testing.assert_allclose(twice["w"], m, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(m=mat, c=st.floats(0.05, 1.0))
def test_drag_aggregate_fixed_point(m, c):
    """If every worker equals r, calibration is the identity (lam=0)."""
    hypothesis.assume(_nonzero(m[0]))
    s = m.shape[0]
    ups = {"w": jnp.asarray(np.tile(m[0], (s, 1)))}
    r = {"w": jnp.asarray(m[0])}
    delta, lams = drag.aggregate(ups, r, c)
    np.testing.assert_allclose(delta["w"], m[0], rtol=1e-4, atol=1e-4)
    assert float(jnp.max(jnp.abs(lams))) < 1e-3


@settings(max_examples=15, deadline=None)
@given(m=mat)
def test_flash_attention_rows_in_v_hull(m):
    """Causal attention output rows are convex combinations of value rows:
    each output coordinate lies within [min_k v, max_k v]."""
    from repro.kernels import ops as kops

    s, d = m.shape
    hypothesis.assume(s >= 2 and d >= 8)
    v = jnp.asarray(m)[None, None]  # [1, 1, S, d]
    q = jnp.ones_like(v)
    k = jnp.ones_like(v)
    out = kops.flash_attention(q, k, v, causal=True, block_q=8, block_k=8,
                               interpret=True)[0, 0]
    lo = jnp.min(v[0, 0], axis=0) - 1e-4
    hi = jnp.max(v[0, 0], axis=0) + 1e-4
    assert bool(jnp.all(out >= lo[None, :])) and bool(jnp.all(out <= hi[None, :]))


# ------------------------------------------------- flat plane round trips
# ISSUE 3 satellite: tree_unflatten_vector(tree_flatten_vector(t), t) == t
# bit-for-bit across mixed dtypes, empty leaves, scalar leaves, and
# non-contiguous layouts — the invariant the whole flat update plane
# (repro.core.flat) rests on.

_FLOAT_DTYPES = (np.float32, np.float16, "bfloat16")

_leaf_shape = st.sampled_from(
    [(), (1,), (3,), (0,), (2, 3), (4, 1, 2), (1, 0, 5), (3, 2, 1, 2)]
)


@st.composite
def _leaf(draw):
    shape = draw(_leaf_shape)
    dtype = draw(st.sampled_from(_FLOAT_DTYPES))
    base = draw(
        hnp.arrays(
            np.float32,
            shape,
            elements=st.floats(-1e4, 1e4, width=32, allow_nan=False,
                               allow_subnormal=False),
        )
    )
    arr = jnp.asarray(base).astype(dtype)
    if draw(st.booleans()) and len(shape) >= 2:
        # non-contiguous layout: flattening must follow the LOGICAL
        # (row-major) order, not whatever the buffer happens to be
        arr = jnp.swapaxes(arr, 0, 1)
    return arr


@st.composite
def _tree(draw):
    n = draw(st.integers(1, 5))
    leaves = [draw(_leaf()) for _ in range(n)]
    kind = draw(st.sampled_from(["dict", "list", "nested"]))
    if kind == "dict":
        return {f"k{i}": x for i, x in enumerate(leaves)}
    if kind == "list":
        return leaves
    return {"a": leaves[0], "b": {"c": leaves[1:]}}


def _assert_trees_bitwise_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert jax.tree.structure(a) == jax.tree.structure(b)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype and x.shape == y.shape
        np.testing.assert_array_equal(
            np.asarray(x, np.float32), np.asarray(y, np.float32)
        )


@settings(max_examples=50, deadline=None)
@given(t=_tree())
def test_flatten_unflatten_roundtrip_bitwise(t):
    """f32 staging is lossless for every <=32-bit float dtype."""
    vec = pt.tree_flatten_vector(t)
    assert vec.dtype == jnp.float32
    assert vec.shape == (pt.tree_size(t),)
    back = pt.tree_unflatten_vector(vec, t)
    _assert_trees_bitwise_equal(back, t)


@settings(max_examples=50, deadline=None)
@given(t=_tree())
def test_flat_spec_roundtrip_bitwise(t):
    """core.flat's spec-based unflatten agrees with the template-based
    one and restores shapes/dtypes exactly."""
    from repro.core import flat as flat_mod

    spec = flat_mod.spec_of(t)
    assert spec.d == pt.tree_size(t)
    back = flat_mod.unflatten_tree(flat_mod.flatten_tree(t), spec)
    _assert_trees_bitwise_equal(back, t)


@settings(max_examples=25, deadline=None)
@given(t=_tree(), s=st.integers(1, 5))
def test_update_stack_roundtrip_bitwise(t, s):
    """Stacked pytree -> UpdateStack -> stacked pytree is the identity,
    metadata included."""
    from repro.core import flat as flat_mod

    stacked = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (s,) + x.shape), t)
    cids = jnp.arange(s, dtype=jnp.int32) * 7 + 3
    taus = jnp.arange(s, dtype=jnp.int32) % 3
    stack = flat_mod.stack_updates(stacked, client_ids=cids, staleness=taus)
    assert stack.data.shape == (s, pt.tree_size(t))
    _assert_trees_bitwise_equal(stack.to_stacked_pytree(), stacked)
    np.testing.assert_array_equal(np.asarray(stack.client_ids), np.asarray(cids))
    np.testing.assert_array_equal(np.asarray(stack.staleness), np.asarray(taus))


# ---------------------------------------------- sharded ingest round trips
# ISSUE 4 satellite: ANY arrival order and client-id distribution,
# hash-routed into p pods (with least-full overflow fallback) and
# flushed hierarchically, matches the single flat buffer fed the same
# arrivals — the sharded plane is a pure re-layout of the flat plane.

_K_SHARD = 8  # buffer capacity (fixed so jit caches per p, not per draw)
_D_SHARD = 12


@settings(max_examples=12, deadline=None)
@given(
    rows=hnp.arrays(
        np.float32,
        (_K_SHARD, _D_SHARD),
        elements=st.floats(-50, 50, width=32, allow_nan=False,
                           allow_subnormal=False),
    ),
    client_ids=st.lists(
        st.integers(0, 2**31 - 1), min_size=_K_SHARD, max_size=_K_SHARD
    ),
    dispatch_rounds=st.lists(
        st.integers(0, 3), min_size=_K_SHARD, max_size=_K_SHARD
    ),
    pods=st.sampled_from([1, 2, 4]),
    overflow_ids=st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=5),
)
def test_sharded_ingest_flush_matches_single_buffer(
    rows, client_ids, dispatch_rounds, pods, overflow_ids
):
    from repro.kernels import ops as kops
    from repro.stream import buffer as buf_mod
    from repro.stream import sharded
    from repro.stream.staleness import make_discount

    hypothesis.assume(all(_nonzero(r) for r in rows))
    params = {"w": jnp.zeros((_D_SHARD,), jnp.float32)}
    b0 = buf_mod.init_buffer(params, _K_SHARD)
    bs = sharded.init_sharded_buffer(params, _K_SHARD, pods)
    for i in range(_K_SHARD):
        g = jnp.asarray(rows[i])
        b0 = buf_mod.ingest(b0, g, dispatch_rounds[i], False, client_ids[i])
        bs = sharded.ingest(bs, g, dispatch_rounds[i], False, client_ids[i])
    # every arrival accepted on both layouts (fallback => no early drops)
    assert int(b0.count) == int(sharded.total_count(bs)) == _K_SHARD
    assert int(b0.drops.sum()) == int(bs.drops.sum()) == 0

    # overflow arrivals past capacity are REFUSED identically on both
    # layouts, and ACCOUNTED identically: same cumulative per-client-
    # hash-bucket drop counters (ISSUE 6 satellite — no silent drops)
    for j, cid in enumerate(overflow_ids):
        g = jnp.asarray(rows[j % _K_SHARD]) + 1.0
        b0 = buf_mod.ingest(b0, g, 0, False, client_id=cid)
        bs = sharded.ingest(bs, g, 0, False, client_id=cid)
    assert int(b0.count) == int(sharded.total_count(bs)) == _K_SHARD
    np.testing.assert_array_equal(np.asarray(b0.drops), np.asarray(bs.drops))
    assert int(b0.drops.sum()) == len(overflow_ids)
    # same multiset of (client, row): pod-major is a permutation of arrival
    def canon(cids, slots):
        a = np.concatenate(
            [np.asarray(cids, np.float64)[:, None], np.asarray(slots, np.float64)],
            axis=1,
        )
        return a[np.lexsort(a.T[::-1])]  # full-row lexicographic order

    np.testing.assert_array_equal(
        canon(b0.client_ids, b0.slots),
        canon(np.asarray(bs.client_ids).reshape(-1),
              np.asarray(bs.slots).reshape(_K_SHARD, -1)),
    )
    # hierarchical flush == single-buffer two-pass flush on the same data
    rnd = 3
    r = jnp.asarray(np.roll(rows[0], 1) + 0.25)
    phi = make_discount("poly", 0.5)
    d0 = kops.drag_calibrate_reduce(
        b0.slots, r, 0.3, "drag",
        discounts=phi(buf_mod.staleness(b0, rnd)),
    )[0]
    ds = sharded.hierarchical_flush(
        bs.slots, r, mode="drag", c=0.3,
        discounts2=phi(sharded.staleness(bs, rnd)),
    )[0]
    scale = max(float(jnp.max(jnp.abs(d0))), 1.0)
    np.testing.assert_allclose(
        np.asarray(ds), np.asarray(d0), rtol=1e-4, atol=1e-4 * scale
    )


# ------------------------------------------------- metrics ring retention
# ISSUE 7 satellite: for ANY push count and capacity, the ring retains
# exactly the last min(n, cap) bundles and drains them oldest-first —
# pinning ring_read's negative-start wraparound arithmetic.


@settings(max_examples=30, deadline=None)
@given(n=st.integers(0, 40), cap=st.integers(1, 8))
def test_metrics_ring_retention_any_push_count(n, cap):
    from repro.obs import flush_bundle, ring_init, ring_push, ring_read

    proto = flush_bundle(rnd=0, fill=1, capacity=cap)
    ring = ring_init(proto, capacity=cap)
    for i in range(n):
        ring = ring_push(ring, flush_bundle(rnd=i, fill=1, capacity=cap))
    got = [e["round"] for e in ring_read(ring)]
    assert got == list(range(max(0, n - cap), n))
    assert int(ring.total) == n


@settings(max_examples=15, deadline=None)
@given(m=mat)
def test_linear_recurrence_zero_decay_is_identity(m):
    """a == 0 => h_t == g_t exactly."""
    from repro.kernels import ops as kops

    g = jnp.asarray(m)[None]  # [1, S, w]
    a = jnp.zeros_like(g)
    out = kops.linear_recurrence(a, g, block_w=g.shape[-1], chunk=g.shape[1],
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), rtol=1e-6, atol=1e-6)
