"""Trust layer tests: divergence-history EMAs, reputation weights,
quarantine, weighted aggregation, and integration with both serving
regimes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import br_drag
from repro.core import pytree as pt
from repro.trust import reputation as trust


CFG = trust.TrustConfig()


class TestHistory:
    def test_first_observation_seeds_ema(self):
        st = trust.init_trust(4)
        idx = jnp.array([1, 3], jnp.int32)
        st = trust.observe(st, idx, jnp.array([1.8, 0.2]), jnp.array([2.0, 1.0]), CFG)
        np.testing.assert_allclose(np.asarray(st.div_ema), [0.0, 1.8, 0.0, 0.2])
        np.testing.assert_allclose(np.asarray(st.seen), [0, 1, 0, 1])

    def test_ema_decay(self):
        st = trust.init_trust(2)
        idx = jnp.array([0], jnp.int32)
        st = trust.observe(st, idx, jnp.array([2.0]), jnp.array([1.0]), CFG)
        st = trust.observe(st, idx, jnp.array([0.0]), jnp.array([1.0]), CFG)
        # 0.8 * 2.0 + 0.2 * 0.0
        np.testing.assert_allclose(np.asarray(st.div_ema)[0], 1.6, rtol=1e-6)

    def test_gate_false_is_noop(self):
        st = trust.init_trust(3)
        idx = jnp.array([0, 1], jnp.int32)
        st2 = trust.observe(
            st, idx, jnp.array([2.0, 2.0]), jnp.array([9.0, 9.0]), CFG,
            gate=jnp.asarray(False),
        )
        np.testing.assert_array_equal(np.asarray(st2.div_ema), np.asarray(st.div_ema))
        np.testing.assert_array_equal(np.asarray(st2.seen), np.asarray(st.seen))

    def test_duplicate_ids_in_one_flush_count_once(self):
        """A client filling several buffer slots of one flush is one
        observation — it must not burn warmup protection early."""
        st = trust.init_trust(4)
        idx = jnp.array([2, 2, 1], jnp.int32)
        st = trust.observe(
            st, idx, jnp.array([2.0, 2.0, 0.1]), jnp.ones(3), CFG
        )
        np.testing.assert_allclose(np.asarray(st.seen), [0, 1, 1, 0])

    def test_id_folding_bounds_the_table(self):
        """Lazy-stream client ids far beyond the table fold in modulo M."""
        st = trust.init_trust(8)
        idx = jnp.array([8 * 1000 + 5], jnp.int32)
        st = trust.observe(st, idx, jnp.array([1.5]), jnp.array([1.0]), CFG)
        assert float(st.div_ema[5]) == 1.5


class TestReputation:
    def test_warmup_gives_benefit_of_the_doubt(self):
        st = trust.init_trust(2)
        idx = jnp.array([0, 1], jnp.int32)
        st = trust.observe(st, idx, jnp.array([2.0, 0.1]), jnp.array([1.0, 1.0]), CFG)
        w = trust.reputation(st, idx, CFG)
        np.testing.assert_allclose(np.asarray(w), [1.0, 1.0])  # seen < warmup

    def test_persistent_divergence_decays_reputation(self):
        st = trust.init_trust(2)
        idx = jnp.array([0, 1], jnp.int32)
        for _ in range(5):
            st = trust.observe(st, idx, jnp.array([2.0, 0.3]), jnp.array([1.0, 1.0]), CFG)
        w = np.asarray(trust.reputation(st, idx, CFG))
        assert w[0] < 0.05  # sign-flip-grade divergence (cos = -1)
        assert w[1] == 1.0  # heterogeneity-grade divergence stays trusted

    def test_norm_inflation_decays_reputation(self):
        st = trust.init_trust(2)
        idx = jnp.array([0, 1], jnp.int32)
        for _ in range(5):
            st = trust.observe(st, idx, jnp.array([0.1, 0.1]), jnp.array([40.0, 1.5]), CFG)
        w = np.asarray(trust.reputation(st, idx, CFG))
        assert w[0] < 1e-6 and w[1] == 1.0

    def test_quarantine_is_sticky_and_zero_weight(self):
        st = trust.init_trust(2)
        idx = jnp.array([0], jnp.int32)
        for _ in range(5):
            st = trust.observe(st, idx, jnp.array([2.0]), jnp.array([1.0]), CFG)
        assert bool(st.quarantined[0])
        # even after the EMA would recover, the flag holds
        for _ in range(50):
            st = trust.observe(st, idx, jnp.array([0.0]), jnp.array([1.0]), CFG)
        w = np.asarray(trust.reputation(st, idx, CFG))
        assert w[0] == 0.0

    def test_weighted_mean_fallback_uniform_when_all_zero(self):
        stacked = {"w": jnp.arange(6.0).reshape(3, 2)}
        out = trust.weighted_mean(stacked, jnp.zeros(3))
        np.testing.assert_allclose(np.asarray(out["w"]), [2.0, 3.0])

    def test_weighted_br_drag_downweights_flagged_worker(self):
        key = jax.random.PRNGKey(0)
        r = {"w": jax.random.normal(key, (16,))}
        ups = {"w": jnp.stack([r["w"]] * 3 + [-5.0 * r["w"]])}
        uniform, _ = br_drag.aggregate(ups, r, 0.5)
        weighted, _ = br_drag.aggregate(
            ups, r, 0.5, weights=jnp.array([1.0, 1.0, 1.0, 0.0])
        )
        d_uni = float(pt.tree_norm(pt.tree_sub(uniform, r)))
        d_wei = float(pt.tree_norm(pt.tree_sub(weighted, r)))
        assert d_wei < d_uni  # excluding the attacker lands closer to r
        # weights=None stays bit-for-bit the paper mean
        again, _ = br_drag.aggregate(ups, r, 0.5)
        np.testing.assert_array_equal(np.asarray(uniform["w"]), np.asarray(again["w"]))


class TestIntegration:
    def _round_setup(self, algorithm, trust_on, n=6):
        from repro.fl.round import RoundConfig, init_server_state, make_round_fn

        def loss_fn(p, batch):
            return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)

        params = {"w": jnp.zeros((3, 1))}
        cfg = RoundConfig(
            algorithm=algorithm, attack="sign_flipping", local_steps=2, lr=0.1,
            trust=trust_on,
        )
        state = init_server_state(params, n, cfg)
        fn = make_round_fn(loss_fn, cfg, with_root=algorithm == "br_drag")
        key = jax.random.PRNGKey(0)
        # every client and the root share one clean regression task, so
        # honest updates align with r^t and sign-flipped ones oppose it
        x = jax.random.normal(key, (2, 4, 3))
        w_true = jax.random.normal(jax.random.fold_in(key, 1), (3, 1))
        y = x @ w_true
        batches = {
            "x": jnp.broadcast_to(x[None], (n, 2, 4, 3)),
            "y": jnp.broadcast_to(y[None], (n, 2, 4, 1)),
        }
        root = {"x": x, "y": y}
        return fn, state, batches, root, key

    def test_sync_br_drag_trust_accumulates_history(self):
        fn, state, batches, root, key = self._round_setup("br_drag", True)
        mask = jnp.array([True, True, False, False, False, False])
        sel = jnp.arange(6, dtype=jnp.int32)
        for i in range(4):
            state, metrics = fn(state, batches, sel, mask, jax.random.fold_in(key, i), root)
        div = np.asarray(state.trust.div_ema)
        # sign-flipped workers show ~2x the divergence of honest ones
        assert div[:2].min() > div[2:].max()
        assert "trust_weight_mean" in metrics

    def test_trust_requires_reference_algorithm(self):
        from repro.fl.round import RoundConfig, federated_round, init_server_state

        cfg = RoundConfig(algorithm="fedavg", trust=True)
        state = init_server_state({"w": jnp.zeros((3, 1))}, 4, cfg)
        with pytest.raises(ValueError, match="reference direction"):
            federated_round(
                lambda p, b: jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2),
                state, cfg,
                {"x": jnp.zeros((4, 1, 2, 3)), "y": jnp.zeros((4, 1, 2, 1))},
                jnp.arange(4, dtype=jnp.int32), jnp.zeros(4, bool),
                jax.random.PRNGKey(0),
            )

    def test_async_flush_trust_indexes_buffer_client_ids(self):
        from repro.core import drag
        from repro.stream import buffer as buf_mod
        from repro.stream.server import StreamConfig, flush, init_stream_state
        from repro.trust import reputation as trust_mod

        p = {"w": jnp.ones((8,))}
        cfg = StreamConfig(algorithm="drag", buffer_capacity=4, trust=True)
        state = init_stream_state(p, 4, cfg, n_clients=10)
        key = jax.random.PRNGKey(0)
        # two flushes: bootstrap (gated, no observation), then observed
        for rnd in range(2):
            buf = state.buffer
            for i in range(4):
                g = {"w": jax.random.normal(jax.random.fold_in(key, 10 * rnd + i), (8,))}
                buf = buf_mod.ingest(buf, g, rnd, i == 0, client_id=i + 3)
            params, dstate, r2, buf, adv, trust_state, m = flush(
                None, cfg, state.params, state.drag, state.round, buf, key,
                adv_state=state.adversary, trust_state=state.trust,
            )
            state = state._replace(
                params=params, drag=dstate, round=r2, buffer=buf, trust=trust_state
            )
        seen = np.asarray(state.trust.seen)
        assert seen[3:7].sum() == 4  # exactly the buffered ids, exactly once
        assert seen[[0, 1, 2, 7, 8, 9]].sum() == 0

    def test_scenario_trust_beats_fedavg_under_ipm(self):
        """End to end on the scenario lab: trust-weighted BR-DRAG keeps
        final loss below plain FedAvg under aggregate-reversing IPM."""
        from repro.adversary.scenarios import Scenario, run_scenario

        kw = dict(attack="ipm", attack_kw=(("eps", 2.0),), rounds=30, seed=3)
        fed = run_scenario(Scenario(aggregator="fedavg", **kw))
        tru = run_scenario(Scenario(aggregator="br_drag_trust", **kw))
        assert tru["final_loss"] < fed["final_loss"]
