"""End-to-end behaviour tests for the whole system (paper protocol +
framework plumbing together)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pytree as pt
from repro.fl import ExperimentConfig, run_experiment
from repro.fl.round import RoundConfig, init_server_state, make_round_fn
from repro.models import cnn


def test_full_fl_loop_improves_over_init():
    """40 workers, S=10, U=5 (exact paper protocol) for a short run."""
    exp = ExperimentConfig(
        dataset="emnist",
        model="mlp",
        n_workers=40,
        n_selected=10,
        local_steps=5,
        batch_size=10,
        rounds=15,
        beta=0.5,
        algorithm="drag",
        c=0.1,
        eval_every=5,
        seed=0,
    )
    hist = run_experiment(exp)
    assert hist["final_accuracy"] > 1.5 / 47  # solidly above chance
    assert len(hist["accuracy"]) == 3


def test_round_fn_is_pure_and_deterministic():
    init_fn, apply_fn = cnn.MODELS["mlp"]
    params = init_fn(jax.random.PRNGKey(0), 16, 8, 5)

    def loss_fn(p, b):
        return cnn.classification_loss(apply_fn, p, b)

    cfg = RoundConfig(algorithm="drag", local_steps=2, lr=0.05)
    fn = make_round_fn(loss_fn, cfg, False)
    batches = {
        "x": jax.random.normal(jax.random.PRNGKey(1), (4, 2, 6, 16)),
        "y": jnp.zeros((4, 2, 6), jnp.int32),
    }
    sel = jnp.arange(4, dtype=jnp.int32)
    mal = jnp.zeros(4, bool)
    s1 = init_server_state(params, 8)
    s2 = init_server_state(params, 8)
    out1, m1 = fn(s1, batches, sel, mal, jax.random.PRNGKey(2))
    out2, m2 = fn(s2, batches, sel, mal, jax.random.PRNGKey(2))
    np.testing.assert_allclose(
        pt.tree_flatten_vector(out1.params), pt.tree_flatten_vector(out2.params)
    )


def test_drag_zero_comm_overhead_claim():
    """DRAG uploads exactly one update pytree per worker per round — the
    same payload as FedAvg (paper §III-C 'no extra communication')."""
    from repro.core import drag

    params = {"w": jnp.zeros((4, 4))}
    ups = {"w": jax.random.normal(jax.random.PRNGKey(0), (6, 4, 4))}
    state = drag.init_state(params)
    # worker->PS payload is v_m: same structure/size as g_m
    _, st1, _ = drag.round_step(params, state, ups, alpha=0.3, c=0.2)
    v, lam = drag.calibrate_worker(pt.tree_index(ups, 0), st1.reference, 0.2)
    assert jax.tree.structure(v) == jax.tree.structure(pt.tree_index(ups, 0))
    assert pt.tree_size(v) == pt.tree_size(pt.tree_index(ups, 0))


def test_checkpoint_roundtrip_of_server_state():
    import tempfile

    from repro import checkpoint

    init_fn, _ = cnn.MODELS["mlp"]
    params = init_fn(jax.random.PRNGKey(0), 10, 6, 3)
    state = init_server_state(params, 4)
    flat = {"params": state.params, "reference": state.drag.reference}
    with tempfile.TemporaryDirectory() as td:
        checkpoint.save(td, flat, step=1)
        restored = checkpoint.restore(td, flat)
    np.testing.assert_allclose(
        pt.tree_flatten_vector(restored["params"]), pt.tree_flatten_vector(flat["params"])
    )


def test_valid_pairs_grid_is_complete():
    from repro.configs import valid_pairs

    pairs = list(valid_pairs())
    assert len(pairs) == 40  # 10 archs x 4 shapes
    skips = [(a, s, r) for a, s, ok, r in pairs if not ok]
    # hubert: 2 decode skips; 4 full-attention long_500k skips
    assert len(skips) == 6, skips
    runnable = [(a, s) for a, s, ok, _ in pairs if ok]
    assert ("falcon-mamba-7b", "long_500k") in runnable
    assert ("starcoder2-3b", "long_500k") in runnable
    assert ("llama4-scout-17b-a16e", "long_500k") in runnable
