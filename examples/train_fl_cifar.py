"""End-to-end FL training driver (the paper's §VI protocol, full knobs).

Trains the paper's CIFAR CNN for a few hundred rounds with any
aggregation algorithm / attack combination, with periodic evaluation and
checkpointing.

    PYTHONPATH=src python examples/train_fl_cifar.py \
        --algorithm drag --rounds 200 --beta 0.1 --c 0.25
    PYTHONPATH=src python examples/train_fl_cifar.py \
        --algorithm br_drag --attack sign_flipping --malicious 0.3
"""
import argparse
import json
import os

from repro import checkpoint
from repro.fl import ExperimentConfig, run_experiment


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cifar10", choices=["emnist", "cifar10", "cifar100"])
    ap.add_argument("--algorithm", default="drag")
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--workers", type=int, default=40)
    ap.add_argument("--selected", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=10)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--beta", type=float, default=0.1)
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--c", type=float, default=0.25)
    ap.add_argument("--c-br", type=float, default=0.5)
    ap.add_argument("--attack", default="none",
                    choices=["none", "noise_injection", "sign_flipping", "label_flipping"])
    ap.add_argument("--malicious", type=float, default=0.0)
    ap.add_argument("--eval-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs/fl")
    args = ap.parse_args()

    model = {"emnist": "emnist_cnn", "cifar10": "cifar10_cnn", "cifar100": "cifar100_cnn"}[
        args.dataset
    ]
    exp = ExperimentConfig(
        dataset=args.dataset,
        model=model,
        n_workers=args.workers,
        n_selected=args.selected,
        rounds=args.rounds,
        local_steps=args.local_steps,
        batch_size=args.batch_size,
        lr=args.lr,
        beta=args.beta,
        algorithm=args.algorithm,
        attack=args.attack,
        malicious_fraction=args.malicious,
        alpha=args.alpha,
        c=args.c,
        c_br=args.c_br,
        eval_every=args.eval_every,
        seed=args.seed,
    )
    os.makedirs(args.out, exist_ok=True)
    name = f"{args.dataset}_{args.algorithm}_{args.attack}_m{args.malicious}_b{args.beta}"

    def progress(m):
        print(f"round {m['round']:4d}  acc={m['accuracy']:.4f}", flush=True)

    hist = run_experiment(exp, progress=progress)
    with open(os.path.join(args.out, name + ".json"), "w") as f:
        json.dump({"config": vars(args), "history": hist}, f, indent=2)
    print(f"final accuracy: {hist['final_accuracy']:.4f} -> {args.out}/{name}.json")


if __name__ == "__main__":
    main()
