"""End-to-end FL training driver (the paper's §VI protocol, full knobs).

Trains the paper's CIFAR CNN for a few hundred rounds with any
aggregation algorithm / attack combination, with periodic evaluation.
The CLI flags build one declarative ``repro.api.ExperimentSpec``; the
run record written next to the history IS the spec
(``spec.to_dict()``), so a run is reproducible from its own JSON:

    PYTHONPATH=src python examples/train_fl_cifar.py \
        --algorithm drag --rounds 200 --beta 0.1 --c 0.25
    PYTHONPATH=src python examples/train_fl_cifar.py \
        --algorithm br_drag --attack sign_flipping --malicious 0.3
"""
import argparse
import json
import os

from repro.api import (
    AggregationSpec,
    AttackSpec,
    DataSpec,
    ExperimentSpec,
    ModelSpec,
    SyncRegime,
    compile,
)

MODELS = {"emnist": "emnist_cnn", "cifar10": "cifar10_cnn", "cifar100": "cifar100_cnn"}


def build_spec(
    dataset: str = "cifar10",
    algorithm: str = "drag",
    rounds: int = 200,
    workers: int = 40,
    selected: int = 10,
    local_steps: int = 5,
    batch_size: int = 10,
    lr: float = 0.01,
    beta: float = 0.1,
    alpha: float = 0.25,
    c: float = 0.25,
    c_br: float = 0.5,
    attack: str = "none",
    malicious: float = 0.0,
    eval_every: int = 20,
    seed: int = 0,
) -> ExperimentSpec:
    return ExperimentSpec(
        data=DataSpec(
            dataset=dataset,
            n_workers=workers,
            beta=beta,
            malicious_fraction=malicious,
        ),
        model=ModelSpec(MODELS[dataset]),
        aggregation=AggregationSpec(
            algorithm=algorithm, alpha=alpha, c=c, c_br=c_br
        ),
        attack=AttackSpec(attack),
        regime=SyncRegime(
            rounds=rounds,
            n_selected=selected,
            local_steps=local_steps,
            batch_size=batch_size,
            lr=lr,
            eval_every=eval_every,
        ),
        seed=seed,
    )


def specs() -> list[tuple[str, ExperimentSpec]]:
    """Default spec (spec-matrix CI validation)."""
    return [("train_fl_cifar/default", build_spec(rounds=2, eval_every=1))]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cifar10", choices=sorted(MODELS))
    ap.add_argument("--algorithm", default="drag")
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--workers", type=int, default=40)
    ap.add_argument("--selected", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=10)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--beta", type=float, default=0.1)
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--c", type=float, default=0.25)
    ap.add_argument("--c-br", type=float, default=0.5)
    ap.add_argument("--attack", default="none",
                    choices=["none", "noise_injection", "sign_flipping", "label_flipping"])
    ap.add_argument("--malicious", type=float, default=0.0)
    ap.add_argument("--eval-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs/fl")
    args = ap.parse_args()

    spec = build_spec(
        dataset=args.dataset,
        algorithm=args.algorithm,
        rounds=args.rounds,
        workers=args.workers,
        selected=args.selected,
        local_steps=args.local_steps,
        batch_size=args.batch_size,
        lr=args.lr,
        beta=args.beta,
        alpha=args.alpha,
        c=args.c,
        c_br=args.c_br,
        attack=args.attack,
        malicious=args.malicious,
        eval_every=args.eval_every,
        seed=args.seed,
    )
    os.makedirs(args.out, exist_ok=True)
    name = (f"{args.dataset}_{args.algorithm}_{args.attack}"
            f"_m{args.malicious}_b{args.beta}")

    def progress(m):
        print(f"round {m['round']:4d}  acc={m['accuracy']:.4f}", flush=True)

    hist = compile(spec).run(progress=progress)
    with open(os.path.join(args.out, name + ".json"), "w") as f:
        json.dump({"spec": spec.to_dict(), "history": hist}, f, indent=2)
    print(f"final accuracy: {hist['final_accuracy']:.4f} -> {args.out}/{name}.json")


if __name__ == "__main__":
    main()
