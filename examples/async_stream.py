"""Async streaming FL: staleness-aware DRAG on an event-driven server.

Clients arrive with heterogeneous latency (systematic stragglers), train
against whatever model version they were dispatched with, and their
uploads land in a fixed-capacity ingest buffer; the global model advances
whenever the buffer fills, discounting each update's DoD by its staleness
phi(tau) = (1 + tau)^-a.  A Byzantine variant runs BR-DRAG with 40%
sign-flipping attackers — fully asynchronously.

Both runs are declared as ``repro.api.ExperimentSpec`` values with an
:class:`~repro.api.AsyncRegime` and compiled onto the stream engine.

    PYTHONPATH=src python examples/async_stream.py
"""
import dataclasses

from repro.api import (
    AggregationSpec,
    AsyncRegime,
    AttackSpec,
    DataSpec,
    ExperimentSpec,
    ModelSpec,
    compile,
)

REGIME = AsyncRegime(
    flushes=30,
    concurrency=16,
    buffer_capacity=8,
    latency="straggler",
    local_steps=5,
    batch_size=10,
    eval_every=10,
)
BASE = ExperimentSpec(
    data=DataSpec(dataset="emnist", n_workers=20, beta=0.1),
    model=ModelSpec("mlp"),
    regime=REGIME,
    seed=0,
)


def specs() -> list[tuple[str, ExperimentSpec]]:
    """The two runs, as data (the spec-matrix CI job validates these)."""
    drag = dataclasses.replace(
        BASE,
        aggregation=AggregationSpec("drag", c=0.25),
        regime=dataclasses.replace(REGIME, discount="poly"),
    )
    byz = dataclasses.replace(
        BASE,
        aggregation=AggregationSpec("br_drag"),
        attack=AttackSpec("sign_flipping"),
        data=dataclasses.replace(
            BASE.data, malicious_fraction=0.4, root_samples=1000
        ),
        regime=dataclasses.replace(REGIME, discount="exp"),
    )
    return [("drag_poly", drag), ("br_drag_byz", byz)]


def main() -> None:
    def show(m):
        print(
            f"  flush {m['flush']:3d}  acc={m['accuracy']:.3f}  "
            f"staleness={m['staleness_mean']:.2f}  phi={m['discount_mean']:.2f}"
        )

    (_, spec_drag), (_, spec_byz) = specs()
    print("== async DRAG, polynomial staleness discount ==")
    h = compile(spec_drag).run(progress=show)
    print(f"  {h['updates_total']} updates ingested, "
          f"{h['updates_per_wall_s']:.1f} upd/s wall, "
          f"virtual horizon {h['virtual_time'][-1]:.1f}")

    print("== async BR-DRAG, 40% sign-flipping Byzantine clients ==")
    h_br = compile(spec_byz).run(progress=show)
    print(f"\nfinal accuracy: drag={h['final_accuracy']:.3f} "
          f"br_drag@40%byz={h_br['final_accuracy']:.3f}")


if __name__ == "__main__":
    main()
