"""Async streaming FL: staleness-aware DRAG on an event-driven server.

Clients arrive with heterogeneous latency (systematic stragglers), train
against whatever model version they were dispatched with, and their
uploads land in a fixed-capacity ingest buffer; the global model advances
whenever the buffer fills, discounting each update's DoD by its staleness
phi(tau) = (1 + tau)^-a.  A Byzantine variant runs BR-DRAG with 40%
sign-flipping attackers — fully asynchronously.

    PYTHONPATH=src python examples/async_stream.py
"""
from repro.stream import StreamExperimentConfig, run_stream_experiment


def main() -> None:
    common = dict(
        dataset="emnist",
        model="mlp",
        n_workers=20,
        concurrency=16,
        flushes=30,
        buffer_capacity=8,
        latency="straggler",
        local_steps=5,
        batch_size=10,
        beta=0.1,
        eval_every=10,
        seed=0,
    )

    def show(m):
        print(
            f"  flush {m['flush']:3d}  acc={m['accuracy']:.3f}  "
            f"staleness={m['staleness_mean']:.2f}  phi={m['discount_mean']:.2f}"
        )

    print("== async DRAG, polynomial staleness discount ==")
    h = run_stream_experiment(
        StreamExperimentConfig(algorithm="drag", c=0.25, discount="poly", **common),
        progress=show,
    )
    print(f"  {h['updates_total']} updates ingested, "
          f"{h['updates_per_wall_s']:.1f} upd/s wall, "
          f"virtual horizon {h['virtual_time'][-1]:.1f}")

    print("== async BR-DRAG, 40% sign-flipping Byzantine clients ==")
    h_br = run_stream_experiment(
        StreamExperimentConfig(
            algorithm="br_drag", attack="sign_flipping", malicious_fraction=0.4,
            discount="exp", root_samples=1000, **common,
        ),
        progress=show,
    )
    print(f"\nfinal accuracy: drag={h['final_accuracy']:.3f} "
          f"br_drag@40%byz={h_br['final_accuracy']:.3f}")


if __name__ == "__main__":
    main()
