"""Production-regime FL round on a multi-device mesh (runs on CPU host
devices; the same code drives the 512-chip dry-run).

Spawns itself with XLA_FLAGS so the demo works from a plain shell:

    PYTHONPATH=src python examples/production_fl_round.py --arch qwen2.5-14b
"""
import argparse
import os
import subprocess
import sys

INNER = """
import jax, jax.numpy as jnp, time
from repro.configs import get_arch
from repro.launch.train import make_fl_round_step, FLStepConfig
from repro.models import transformer as T
from repro.data.synthetic import synth_token_batch

arch_id = %(arch)r
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_arch(arch_id, smoke=True)
fl = FLStepConfig(aggregator=%(agg)r, local_steps=2, lr=0.01, c=0.1)
step, _ = make_fl_round_step(cfg, mesh, "data", fl, jnp.float32)

key = jax.random.PRNGKey(0)
params = T.init_params(key, cfg)
reference = jax.tree.map(jnp.zeros_like, params)
U, B, S = 2, 8, 64
tb = synth_token_batch(key, U * B, S, cfg.vocab)
batch = {k: v.reshape(U, B, S) for k, v in tb.items()}
root = {k: v[:, :2] for k, v in batch.items()}

with mesh:
    for r in range(4):
        t0 = time.time()
        args = (params, reference, batch) + ((root,) if %(agg)r == "br_drag" else ())
        params, reference, m = step(*args)
        jax.block_until_ready(m["delta_norm"])
        print(f"round {r}: DoD={float(m['dod_mean']):.4f} "
              f"|delta|={float(m['delta_norm']):.4f} ({time.time()-t0:.2f}s)")
print("4 clients x", U, "local steps per round; one pmean per round - done")
"""


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--aggregator", default="drag", choices=["drag", "br_drag", "fedavg"])
    args = ap.parse_args()
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("PYTHONPATH", "src")
    code = INNER % {"arch": args.arch, "agg": args.aggregator}
    raise SystemExit(subprocess.call([sys.executable, "-c", code], env=env))


if __name__ == "__main__":
    main()
