"""Decentralized DRAG (the paper's §VII future work): no parameter
server, gossip over a ring vs a complete graph.

    PYTHONPATH=src python examples/decentralized_drag.py
"""
import jax
import jax.numpy as jnp

from repro.core import decentralized as D
from repro.core import pytree as pt
from repro.models import cnn


def _skewed_data(key, n_samples, d_in, classes, skew_class):
    """Class-conditional Gaussians with 50% of mass on ``skew_class``."""
    kp, kl, kn = jax.random.split(key, 3)
    protos = jax.random.normal(jax.random.PRNGKey(99), (classes, d_in))
    p = jnp.full((classes,), 0.5 / (classes - 1)).at[skew_class].set(0.5)
    y = jax.random.choice(kl, classes, (n_samples,), p=p)
    x = protos[y] + 0.4 * jax.random.normal(kn, (n_samples, d_in))
    return x, y


def main():
    n, d_in, classes = 8, 16, 5
    key = jax.random.PRNGKey(0)
    init_fn, apply_fn = cnn.MODELS["mlp"]
    params = init_fn(key, d_in, 8, classes)

    # heterogeneous local data: each worker sees a class-skewed slice
    data = [
        _skewed_data(jax.random.fold_in(key, i), 256, d_in, classes, i % classes)
        for i in range(n)
    ]

    def local_update(p, xy):
        x, y = xy

        def loss(p):
            return cnn.classification_loss(apply_fn, p, {"x": x, "y": y})

        g = jax.grad(loss)(p)
        return jax.tree.map(lambda gg: -0.05 * gg, g)

    params_st = jax.tree.map(lambda x: jnp.tile(x[None], (n,) + (1,) * x.ndim), params)
    refs_st = pt.tree_zeros_like(params_st)

    for topo in ("complete", "ring"):
        w = D.TOPOLOGIES[topo](n)
        p, r = params_st, refs_st
        for t in range(30):
            ups = jax.vmap(local_update)(p, tuple(map(jnp.stack, zip(*data))))
            if t == 0:
                r = ups  # bootstrap reference (eq. 5a, local)
            p, r, lam = D.decentralized_drag_round(p, r, ups, w, c=0.15, alpha=0.25)
        accs = []
        for i in range(n):
            pi = jax.tree.map(lambda x: x[i], p)
            x, y = data[i]
            accs.append(float(cnn.accuracy(apply_fn, pi, {"x": x, "y": y})))
        print(
            f"{topo:9s}: mean local acc {sum(accs)/n:.3f}  "
            f"consensus dist {float(D.consensus_distance(p)):.4f}  "
            f"mean DoD {float(jnp.mean(lam)):.3f}"
        )


if __name__ == "__main__":
    main()
