"""Quickstart: 30 rounds of DRAG vs FedAvg on heterogeneous synthetic
EMNIST (Dirichlet beta=0.1, 20 workers, 8 selected/round, U=5).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.fl import ExperimentConfig, run_experiment


def main() -> None:
    common = dict(
        dataset="emnist",
        model="emnist_cnn",
        n_workers=20,
        n_selected=8,
        rounds=30,
        beta=0.1,
        eval_every=10,
        seed=0,
    )
    print("== FedAvg baseline ==")
    h_avg = run_experiment(
        ExperimentConfig(algorithm="fedavg", **common),
        progress=lambda m: print(f"  round {m['round']:3d}  acc={m['accuracy']:.3f}"),
    )
    print("== DRAG (this paper) ==")
    h_drag = run_experiment(
        ExperimentConfig(algorithm="drag", c=0.25, alpha=0.25, **common),
        progress=lambda m: print(
            f"  round {m['round']:3d}  acc={m['accuracy']:.3f}  DoD={m['dod_mean']:.3f}"
        ),
    )
    print(f"\nfinal accuracy: fedavg={h_avg['final_accuracy']:.3f} "
          f"drag={h_drag['final_accuracy']:.3f}")


if __name__ == "__main__":
    main()
