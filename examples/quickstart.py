"""Quickstart: 30 rounds of DRAG vs FedAvg on heterogeneous synthetic
EMNIST (Dirichlet beta=0.1, 20 workers, 8 selected/round, U=5), driven
through the declarative experiment plane (``repro.api``): one
``ExperimentSpec`` per run, validated against the live registries and
compiled onto the synchronous engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

from repro.api import (
    AggregationSpec,
    DataSpec,
    ExperimentSpec,
    ModelSpec,
    SyncRegime,
    compile,
)

BASE = ExperimentSpec(
    data=DataSpec(dataset="emnist", n_workers=20, beta=0.1),
    model=ModelSpec("emnist_cnn"),
    regime=SyncRegime(rounds=30, n_selected=8, eval_every=10),
    seed=0,
)


def specs() -> list[tuple[str, ExperimentSpec]]:
    """The two runs, as data (the spec-matrix CI job validates these)."""
    return [
        ("fedavg", dataclasses.replace(BASE, aggregation=AggregationSpec("fedavg"))),
        ("drag", dataclasses.replace(
            BASE, aggregation=AggregationSpec("drag", c=0.25, alpha=0.25)
        )),
    ]


def main() -> None:
    (_, spec_avg), (_, spec_drag) = specs()

    print("== FedAvg baseline ==")
    h_avg = compile(spec_avg).run(
        progress=lambda m: print(f"  round {m['round']:3d}  acc={m['accuracy']:.3f}"),
    )
    print("== DRAG (this paper) ==")
    h_drag = compile(spec_drag).run(
        progress=lambda m: print(
            f"  round {m['round']:3d}  acc={m['accuracy']:.3f}  DoD={m['dod_mean']:.3f}"
        ),
    )
    print(f"\nfinal accuracy: fedavg={h_avg['final_accuracy']:.3f} "
          f"drag={h_drag['final_accuracy']:.3f}")
    # a spec is plain data — this JSON is the whole experiment
    print(f"\nspec (serialized): {spec_drag.to_json()}")


if __name__ == "__main__":
    main()
