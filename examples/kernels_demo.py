"""Pallas kernel showcase — runs every kernel in interpret mode on CPU
and checks it against its jnp oracle.

    PYTHONPATH=src python examples/kernels_demo.py

On a real TPU the same `repro.kernels.ops` calls compile to Mosaic; the
analytic HBM-traffic numbers printed here are the §Roofline terms the
kernels are accountable to (BlockSpec I/O, not fusion-dependent).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels import flash_attention as fa
from repro.kernels import linear_recurrence as lr
from repro.kernels import selective_scan as ssk


def banner(s):
    print(f"\n=== {s} " + "=" * max(8, 60 - len(s)))


def check(name, got, want, tol=1e-4):
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32))))
    print(f"  {name:<42s} max|err| = {err:.2e}  {'OK' if err < tol else 'FAIL'}")
    assert err < tol, name


def main():
    key = jax.random.PRNGKey(0)

    banner("DRAG fused calibration (eqs. 10+11 / 15)")
    g = jax.random.normal(key, (8, 4096))
    r = jax.random.normal(jax.random.fold_in(key, 1), (4096,))
    for mode in ("drag", "br_drag"):
        v, lam, delta = ops.drag_calibrate(g, r, 0.25, mode)
        v_ref, lam_ref = ref.drag_calibrate_ref(g, r, 0.25, mode)
        check(f"drag_calibrate[{mode}] v", v, v_ref)
        check(f"drag_calibrate[{mode}] lambda", lam, lam_ref)
    print("  one HBM pass for dots/norms + one for the blend (vs 4 naive)")

    banner("Flat serving path: whole flush = 2 HBM passes (ISSUE 3)")
    # This is what repro.fl.round / repro.stream.server actually execute:
    # staleness discounts + trust weights folded into the blend_reduce
    # epilogue, trust signals free from the phase-1 scalars, and the
    # calibrated stack V NEVER materialised.
    from repro.trust.reputation import signals_from_stats

    discounts = jnp.linspace(1.0, 0.5, 8)  # phi(tau) per buffered slot
    weights = jnp.linspace(0.25, 1.0, 8)  # trust reputations
    delta, lam, stats = ops.drag_calibrate_reduce(
        g, r, 0.25, "drag", discounts=discounts, weights=weights
    )
    # oracle: materialise V, weighted mean, separate trust pass
    a, b, lam_ref = ref.calibrate_coeffs(*ref.dot_norms_ref(g, r), 0.25, "drag",
                                         discounts)
    v_ref = ref.blend_ref(g, r, a, b)
    w = weights / jnp.sum(weights)
    check("flush delta (2-pass vs oracle)", delta, w @ v_ref.astype(jnp.float32))
    check("flush lambda", lam, lam_ref)
    div, nr = signals_from_stats(*stats)
    gn = jnp.linalg.norm(g, axis=1)
    rn = jnp.linalg.norm(r)
    check("trust divergence (free from pass 1)", div,
          1.0 - (g @ r) / (gn * rn), tol=1e-3)
    check("trust norm ratio (free from pass 1)", nr, gn / rn, tol=1e-3)
    print("  dot_norms + blend_reduce: 2 HBM passes over G for the WHOLE")
    print("  trust-weighted staleness-aware flush; V:[S,d] never written")

    banner("Weiszfeld geometric median (RFA/RAGA)")
    z = ops.geometric_median(g, iters=8)
    z_ref = g.astype(jnp.float32)
    zz = jnp.mean(z_ref, 0)
    for _ in range(8):
        w = 1.0 / jnp.maximum(jnp.linalg.norm(z_ref - zz, axis=1), 1e-8)
        zz = (w @ z_ref) / jnp.sum(w)
    check("geometric_median (8 iters)", z, zz, tol=1e-3)

    banner("Trimmed mean")
    tm = ops.trimmed_mean(g, trim=2)
    check("trimmed_mean", tm, ref.trimmed_mean_ref(g, 2))

    banner("Flash attention (online softmax, GQA)")
    b, h, hkv, s, dh = 2, 8, 2, 512, 64
    q = jax.random.normal(jax.random.fold_in(key, 2), (b, h, s, dh), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 3), (b, hkv, s, dh), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 4), (b, hkv, s, dh), jnp.bfloat16)
    o = ops.flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    o_ref = ref.flash_attention_ref(q, k, v, causal=True)
    check("flash_attention causal GQA-4", o, o_ref, tol=3e-2)
    naive = 4 * b * h * s * s  # f32 score bytes, one materialisation
    print(f"  kernel I/O {fa.io_bytes(b, h, hkv, s, s, dh)/1e6:.1f} MB  "
          f"vs naive score-chain >= {naive/1e6:.1f} MB")

    banner("Mamba selective scan (VMEM-resident state)")
    bs, sl, di, ds = 1, 256, 256, 16
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 5), (bs, sl, di))) * 0.1
    x = jax.random.normal(jax.random.fold_in(key, 6), (bs, sl, di))
    bm = jax.random.normal(jax.random.fold_in(key, 7), (bs, sl, ds))
    cm = jax.random.normal(jax.random.fold_in(key, 8), (bs, sl, ds))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 9), (di, ds)) * 0.3)
    y = ops.selective_scan(dt, x, bm, cm, a, block_di=128, chunk=64)
    check("selective_scan", y, ref.selective_scan_ref(dt, x, bm, cm, a))
    print(f"  kernel I/O {ssk.io_bytes(bs, sl, di, ds)/1e6:.2f} MB "
          f"(independent of d_state and scan depth)")

    banner("RG-LRU linear recurrence")
    aa = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 10), (1, 256, 256)))
    gg = jax.random.normal(jax.random.fold_in(key, 11), (1, 256, 256)) * 0.5
    hh = ops.linear_recurrence(aa, gg, block_w=128, chunk=64)
    check("linear_recurrence", hh, ref.linear_recurrence_ref(aa, gg), tol=1e-4)
    print(f"  kernel I/O {lr.io_bytes(1, 256, 256)/1e6:.2f} MB (3 passes of [B,S,w])")

    print("\nall kernels match their oracles.")


if __name__ == "__main__":
    main()
