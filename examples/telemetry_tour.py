"""Telemetry tour: watch a BR-DRAG defense run from the inside.

One async BR-DRAG run under a SCHEDULED ALIE onset (benign until flush
``ONSET``, then 40% colluding clients), with the observability plane
(``repro.obs``) recording everything it is allowed to see:

  * the jit-safe ``MetricsBundle`` ring — per-flush DoD / divergence
    histograms, blend coefficients, trust-reputation distribution and
    quarantine count, staleness discounts, buffer drops — assembled
    INSIDE the jitted flush from signals the two-pass kernels already
    computed (zero extra HBM passes, numerics untouched);
  * the diagnosis layer (``MonitorSpec``): O(1) CUSUM + Page–Hinkley
    change-point detectors riding the jitted flush, raising typed
    ``alert`` events when the divergence regime shifts at attack onset;
  * host-side trace spans around the engine's boundaries
    (ingest / flush / root_reference / client_update / eval);
  * a JSONL event log and a Chrome/Perfetto trace — open
    ``out/telemetry_tour_trace.json`` at https://ui.perfetto.dev to see
    the wall-clock anatomy of the event loop (alerts appear as instants);
  * forensics + a markdown run report (``out/telemetry_tour_report.md``)
    joining the span breakdown with the alert and flush timelines.

Everything is declared on the spec: ``TelemetrySpec(enabled=True, ...)``
is the only difference from an unrecorded run, and flipping it off
provably changes nothing but the observation.

    PYTHONPATH=src python examples/telemetry_tour.py
"""
import os

from repro.api import (
    AggregationSpec,
    AsyncRegime,
    AttackSpec,
    DataSpec,
    ExperimentSpec,
    ModelSpec,
    MonitorSpec,
    TelemetrySpec,
    TrustSpec,
    compile,
)
from repro.obs import alert_latency, incident_timeline, write_report

# artifacts land in out/ (gitignored), never the repo root
OUT_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "out")
JSONL = os.path.join(OUT_DIR, "telemetry_tour_events.jsonl")
PERFETTO = os.path.join(OUT_DIR, "telemetry_tour_trace.json")
REPORT = os.path.join(OUT_DIR, "telemetry_tour_report.md")

#: first flush the ALIE collusion is active (earlier flushes are benign,
#: so the monitor's EWMA baselines settle on honest traffic first)
ONSET = 14


def specs() -> list[tuple[str, ExperimentSpec]]:
    """The run, as data (the spec-matrix CI job validates it)."""
    spec = ExperimentSpec(
        data=DataSpec(
            dataset="emnist", n_workers=20, beta=0.1,
            malicious_fraction=0.4, root_samples=1000,
        ),
        model=ModelSpec("mlp"),
        aggregation=AggregationSpec("br_drag"),
        attack=AttackSpec("schedule", {"phases": ((ONSET, "alie"),)}),
        trust=TrustSpec(enabled=True),
        regime=AsyncRegime(
            flushes=32, concurrency=12, buffer_capacity=8,
            latency="straggler", local_steps=3, batch_size=8,
            discount="poly", eval_every=4,
        ),
        telemetry=TelemetrySpec(
            enabled=True, ring_capacity=32, jsonl=JSONL, perfetto=PERFETTO,
            # the defaults are tuned on the adversary lab's clean synthetic
            # cells; this short real-data run is noisier and ALIE is built
            # to hide inside the benign variance, so the tour tightens the
            # thresholds (more sensitivity, still alarm-free before onset)
            monitor=MonitorSpec(
                enabled=True, cusum_h=4.0, cusum_k=0.4, ph_lambda=8.0
            ),
        ),
        seed=0,
    )
    return [("br_drag_alie_onset_recorded", spec)]


def main() -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    (_, spec), = specs()
    print(f"== BR-DRAG vs scheduled ALIE (benign until flush {ONSET}, "
          "then 40% malicious), telemetry + monitor recording ==")
    h = compile(spec).run(
        progress=lambda m: print(
            f"  flush {m['flush']:3d}  acc={m['accuracy']:.3f}  "
            f"staleness={m['staleness_mean']:.2f}"
        )
    )

    tel = h["telemetry"]
    print(f"\nfinal accuracy {h['final_accuracy']:.3f} after "
          f"{h['updates_total']} ingested updates")

    # -- where the wall clock went (host spans, aggregated)
    print("\nspan breakdown (host boundaries):")
    for name, s in sorted(tel["spans"].items(), key=lambda kv: -kv[1]["total_ms"]):
        print(f"  {name:16s} x{s['count']:<4d} total {s['total_ms']:8.1f} ms  "
              f"mean {s['mean_us']:9.1f} us")

    # -- what the flush saw (on-device MetricsBundle ring, oldest first)
    print("\nflush-metrics ring (last 3 of "
          f"{tel['flushes_recorded']} recorded flushes):")
    for b in tel["ring"][-3:]:
        print(f"  round {b['round']:3d}  dod_mean={b['dod_mean']:.3f}  "
              f"div_max={b['div_max']:.3f}  a={b['coeff_a_mean']:.3f} "
              f"b={b['coeff_b_mean']:.3f}  quarantined={b['quarantined']}  "
              f"phi={b['discount_mean']:.2f}")
    print(f"\nbuffer drops by client-hash bucket: {tel['drops_by_bucket']}"
          f"  (total {tel['drops_total']})")

    # -- did the diagnosis layer catch the onset?
    alerts = tel.get("alerts", [])
    lat = alert_latency(alerts, ONSET)
    print(f"\nmonitor: {tel['monitor']['alarms_total']} alarms over "
          f"{tel['monitor']['flushes']} flushes "
          f"(by signal: {tel['monitor']['alarms_by_signal']})")
    for a in alerts:
        print(f"  alert round {a['round']:3d}  {a['signal']:16s} "
              f"value={a['value']:.3f}  score={a['score']:.1f} sigma")
    if lat["detected"]:
        print(f"  -> onset at flush {ONSET} detected with latency "
              f"{lat['latency_flushes']} flushes "
              f"({lat['false_alarms']} pre-onset alarms)")
    else:
        print(f"  -> onset at flush {ONSET} NOT detected "
              "(try a longer run or lower thresholds)")

    # -- flush-by-flush incident timeline around the onset
    print("\nincident timeline (flushes adjacent to the onset):")
    for row in incident_timeline(tel):
        if not row.get("evicted") and abs(row["round"] - ONSET) <= 2:
            mark = " <- ALERT" if row["alerts"] else ""
            print(f"  round {row['round']:3d}  div={row['div_mean']:.3f}  "
                  f"quarantined={row['quarantined']}{mark}")

    # -- the whole story as one markdown artifact
    write_report(
        REPORT, tel, title="Telemetry tour: BR-DRAG vs scheduled ALIE",
        history=h,
    )
    print(f"\nrun report: {REPORT}")
    print(f"event log:  {tel['jsonl']}")
    print(f"trace:      {tel['perfetto']}  <- open at https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
