"""Telemetry tour: watch a BR-DRAG defense run from the inside.

One async BR-DRAG run under the ALIE attack (40% colluding clients),
with the observability plane (``repro.obs``) recording everything it is
allowed to see:

  * the jit-safe ``MetricsBundle`` ring — per-flush DoD / divergence
    histograms, blend coefficients, trust-reputation distribution and
    quarantine count, staleness discounts, buffer drops — assembled
    INSIDE the jitted flush from signals the two-pass kernels already
    computed (zero extra HBM passes, numerics untouched);
  * host-side trace spans around the engine's boundaries
    (ingest / flush / root_reference / client_update / eval);
  * a JSONL event log and a Chrome/Perfetto trace — open
    ``telemetry_tour_trace.json`` at https://ui.perfetto.dev to see the
    wall-clock anatomy of the event loop.

Everything is declared on the spec: ``TelemetrySpec(enabled=True, ...)``
is the only difference from an unrecorded run, and flipping it off
provably changes nothing but the observation.

    PYTHONPATH=src python examples/telemetry_tour.py
"""
import dataclasses

from repro.api import (
    AggregationSpec,
    AsyncRegime,
    AttackSpec,
    DataSpec,
    ExperimentSpec,
    ModelSpec,
    TelemetrySpec,
    TrustSpec,
    compile,
)

JSONL = "telemetry_tour_events.jsonl"
PERFETTO = "telemetry_tour_trace.json"


def specs() -> list[tuple[str, ExperimentSpec]]:
    """The run, as data (the spec-matrix CI job validates it)."""
    spec = ExperimentSpec(
        data=DataSpec(
            dataset="emnist", n_workers=20, beta=0.1,
            malicious_fraction=0.4, root_samples=1000,
        ),
        model=ModelSpec("mlp"),
        aggregation=AggregationSpec("br_drag"),
        attack=AttackSpec("alie"),
        trust=TrustSpec(enabled=True),
        regime=AsyncRegime(
            flushes=12, concurrency=12, buffer_capacity=8,
            latency="straggler", local_steps=3, batch_size=8,
            discount="poly", eval_every=4,
        ),
        telemetry=TelemetrySpec(
            enabled=True, ring_capacity=32, jsonl=JSONL, perfetto=PERFETTO
        ),
        seed=0,
    )
    return [("br_drag_alie_recorded", spec)]


def main() -> None:
    (_, spec), = specs()
    print("== BR-DRAG vs ALIE (40% malicious), telemetry recording ==")
    h = compile(spec).run(
        progress=lambda m: print(
            f"  flush {m['flush']:3d}  acc={m['accuracy']:.3f}  "
            f"staleness={m['staleness_mean']:.2f}"
        )
    )

    tel = h["telemetry"]
    print(f"\nfinal accuracy {h['final_accuracy']:.3f} after "
          f"{h['updates_total']} ingested updates")

    # -- where the wall clock went (host spans, aggregated)
    print("\nspan breakdown (host boundaries):")
    for name, s in sorted(tel["spans"].items(), key=lambda kv: -kv[1]["total_ms"]):
        print(f"  {name:16s} x{s['count']:<4d} total {s['total_ms']:8.1f} ms  "
              f"mean {s['mean_us']:9.1f} us")

    # -- what the flush saw (on-device MetricsBundle ring, oldest first)
    print("\nflush-metrics ring (last 3 of "
          f"{tel['flushes_recorded']} recorded flushes):")
    for b in tel["ring"][-3:]:
        print(f"  round {b['round']:3d}  dod_mean={b['dod_mean']:.3f}  "
              f"div_max={b['div_max']:.3f}  a={b['coeff_a_mean']:.3f} "
              f"b={b['coeff_b_mean']:.3f}  quarantined={b['quarantined']}  "
              f"phi={b['discount_mean']:.2f}")
    print(f"\nbuffer drops by client-hash bucket: {tel['drops_by_bucket']}"
          f"  (total {tel['drops_total']})")

    print(f"\nevent log: {tel['jsonl']}")
    print(f"trace:     {tel['perfetto']}  <- open at https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
