"""Sharded ingest buffer walkthrough — the hierarchical one-psum flush.

    PYTHONPATH=src python examples/sharded_stream.py

Demonstrates, on one CPU device (the emulation path — on a pod mesh the
same program shard_maps with a real psum):

  1. hash routing: client ids spread over per-pod [K/p, d] sub-buffers,
     with the least-full fallback soaking up a crowded pod;
  2. the hierarchical flush: each pod runs the SAME fused flush as the
     single-buffer serving path (one fused_flush kernel here — the
     [K/p, d] sub-stacks are VMEM-resident) over its own rows, and
     everything cross-pod — the partial [d] weighted sums, the scattered
     DoD/trust scalars — meets in exactly ONE psum;
  3. parity: p = 1 is bit-for-bit the single-buffer flush, p > 1 is the
     same math reassociated across pods (~1e-7).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flat as flat_mod
from repro.kernels import instrument
from repro.kernels import ops as kops
from repro.stream import buffer as buf_mod
from repro.stream import sharded


def banner(s):
    print(f"\n=== {s} " + "=" * max(8, 60 - len(s)))


def main():
    key = jax.random.PRNGKey(0)
    params = {"w": jnp.zeros((2048,)), "b": jnp.zeros((64,))}
    K, P = 16, 4

    banner(f"1. hash-routed ingest: K={K} uploads into {P} pods of {K // P}")
    buf = sharded.init_sharded_buffer(params, K, P)
    single = buf_mod.init_buffer(params, K)
    # a crowded tenant: half the clients share pod route_pod(cid)=home
    cids = list(range(100, 100 + K))
    for i, cid in enumerate(cids):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (2048,)),
             "b": jax.random.normal(jax.random.fold_in(key, 500 + i), (64,))}
        home = int(sharded.route_pod(cid, P))
        buf = sharded.ingest(buf, g, i % 3, False, cid)
        single = buf_mod.ingest(single, g, i % 3, False, cid)
        print(f"  client {cid}: home pod {home}, counts now "
              f"{np.asarray(buf.counts).tolist()}")
    assert int(sharded.total_count(buf)) == K  # fallback => nothing dropped

    banner("2. hierarchical flush: one fused pass per pod, ONE psum")
    r = jax.random.normal(jax.random.fold_in(key, 999), (2048 + 64,))
    disc = (1.0 + sharded.staleness(buf, 3).astype(jnp.float32)) ** -0.5
    with instrument.count_collective_calls() as coll:
        with instrument.count_kernel_calls() as kern:
            delta, lam, stats = sharded.hierarchical_flush(
                buf.slots, r, mode="drag", c=0.3, discounts2=disc,
            )
    print(f"  kernel calls: {kern}  (one fused_flush per pod)")
    print(f"  cross-pod reductions: {coll}  <- the ONE psum")
    assert coll == instrument.ONE_PSUM_CALLS
    assert kern["fused_flush"] == P and kern["blend"] == 0
    print(f"  per-flush collective traffic: one [d]={r.shape[0]} partial sum "
          f"+ {3 * K} scalars — O(d), independent of K")

    banner("3. parity vs the single-buffer oracle")
    phi = (1.0 + buf_mod.staleness(single, 3).astype(jnp.float32)) ** -0.5
    d_single = kops.drag_calibrate_reduce(
        single.slots, r, 0.3, "drag", discounts=phi
    )[0]
    err = float(jnp.max(jnp.abs(delta - d_single)))
    print(f"  p={P} vs single buffer: max|err| = {err:.2e} (reassociation)")
    assert err < 1e-5

    buf1 = sharded.init_sharded_buffer(params, K, 1)
    for i, cid in enumerate(cids):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (2048,)),
             "b": jax.random.normal(jax.random.fold_in(key, 500 + i), (64,))}
        buf1 = sharded.ingest(buf1, g, i % 3, False, cid)
    d_p1 = sharded.hierarchical_flush(
        buf1.slots, r, mode="drag", c=0.3, discounts2=phi[None],
    )[0]
    exact = bool((np.asarray(d_p1) == np.asarray(d_single)).all())
    print(f"  p=1 vs single buffer: bit-for-bit = {exact}")
    assert exact

    # egress: the ONE unflatten of the aggregated [d] delta
    delta_tree = flat_mod.unflatten_tree(delta, flat_mod.spec_of(params))
    print(f"  egress unflatten -> {list(delta_tree)} leaves, "
          f"delta_norm = {float(jnp.linalg.norm(delta)):.4f}")
    print("\nsharded plane matches the single-buffer oracle.")


if __name__ == "__main__":
    main()
