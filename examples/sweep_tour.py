"""Sweep-engine tour: a grouped spec grid, the executable cache, and a
client-churn population — all through ``repro.sweep``.

The walkthrough builds a 3 x 2 scalar-knob grid (seeds x Dirichlet
betas) of BR-DRAG cells under sign flipping.  Every cell lowers to the
SAME jaxpr shape, so :func:`repro.sweep.run_sweep` runs the whole grid
as ONE compiled program vmapped over the group axis — and a second
sweep over the same grid is a pure executable-cache hit (zero
compiles).  A churned async cell rides in the same call: populations
are plain spec fields (``AsyncRegime.churn_period`` / ``churn_duty`` /
``diurnal_amp``), so the grid stays declarative data end to end, and
the engine falls back to sequential execution for the cells that have
no group axis.

    PYTHONPATH=src python examples/sweep_tour.py
"""
import dataclasses

from repro.api import (
    AggregationSpec,
    AsyncRegime,
    AttackSpec,
    DataSpec,
    ExperimentSpec,
    ModelSpec,
    SyncRegime,
)
from repro.sweep import ExecutableCache, group_specs, run_sweep

#: the grid's statics: everything here is part of the group key
BASE = ExperimentSpec(
    data=DataSpec(dataset="emnist_small", n_workers=16, beta=0.1,
                  malicious_fraction=0.25, root_samples=256),
    model=ModelSpec("mlp"),
    aggregation=AggregationSpec("br_drag"),
    attack=AttackSpec("sign_flipping"),
    regime=SyncRegime(rounds=6, n_selected=8, local_steps=2, batch_size=8,
                      eval_every=3),
)

#: a living population: clients churn on hash-phased duty windows and
#: arrivals swell diurnally — spec fields, not a new config class
CHURNED = dataclasses.replace(
    BASE,
    aggregation=AggregationSpec("drag"),
    attack=AttackSpec("none"),
    data=dataclasses.replace(BASE.data, malicious_fraction=0.0),
    regime=AsyncRegime(flushes=8, concurrency=8, buffer_capacity=4,
                       local_steps=2, batch_size=8, eval_every=4,
                       churn_period=12.0, churn_duty=0.6,
                       diurnal_amp=0.3, diurnal_period=24.0),
)


def specs() -> list[tuple[str, ExperimentSpec]]:
    """The tour's specs, as data (spec-matrix CI validates these)."""
    grid = [
        (
            f"grid_seed{seed}_beta{beta}",
            dataclasses.replace(
                BASE, data=dataclasses.replace(BASE.data, beta=beta),
                seed=seed,
            ),
        )
        for beta in (0.1, 0.5)
        for seed in (0, 1, 2)
    ]
    return grid + [("churned_async", CHURNED)]


def main() -> None:
    named = specs()
    grid = [s for _, s in named]

    groups = group_specs(grid)
    print(f"{len(grid)} specs -> {len(groups)} groups "
          f"(batched sizes: {[len(g.specs) for g in groups if g.batched]})")

    cache = ExecutableCache()
    result = run_sweep(grid, cache=cache)
    for (name, _), hist in zip(named, result):
        print(f"  {name:24s} final_accuracy={hist['final_accuracy']:.3f}")
    p = result.provenance
    print(f"first sweep: {p['batched_cells']} batched + "
          f"{p['sequential_cells']} sequential cells, "
          f"{p['cache_misses']} compiles, wall {p['wall_s']:.1f}s")

    again = run_sweep(grid, cache=cache, check=False)
    q = again.provenance
    print(f"second sweep: {q['cache_hits']}/{q['groups']} groups from the "
          f"executable cache ({q['cache_misses']} compiles), "
          f"wall {q['wall_s']:.1f}s")
    assert [h["accuracy"] for h in again] == [h["accuracy"] for h in result]
    print("reruns are bit-for-bit identical")


if __name__ == "__main__":
    main()
