"""Serving example: prefill + autoregressive decode with KV caches for
any assigned architecture (reduced smoke variant on CPU).

    PYTHONPATH=src python examples/serve_decode.py --arch falcon-mamba-7b
    PYTHONPATH=src python examples/serve_decode.py --arch starcoder2-3b --steps 24
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_arch
from repro.models import transformer as T


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b", choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=True)
    if not cfg.supports_decode():
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    b, pl_ = args.batch, args.prompt_len
    prompt = jax.random.randint(key, (b, pl_), 0, cfg.vocab)

    cache = T.init_cache(cfg, b, cache_len=pl_ + args.steps)
    step = jax.jit(
        lambda p, tok, pos, c: T.decode_step(p, cfg, tok, pos, c)
    )

    # prefill token-by-token through the cache (smoke-scale; the production
    # path batches this via repro.launch.serve.make_prefill)
    t0 = time.time()
    for t in range(pl_):
        _, _, cache = step(params, prompt[:, t : t + 1], jnp.full((b, 1), t, jnp.int32), cache)
    print(f"prefill {pl_} tokens in {time.time()-t0:.2f}s")

    tok = prompt[:, -1:]
    out = []
    t0 = time.time()
    for t in range(pl_, pl_ + args.steps):
        tok, _, cache = step(params, tok, jnp.full((b, 1), t, jnp.int32), cache)
        out.append(int(tok[0, 0]))
    dt = (time.time() - t0) / args.steps
    print(f"decoded {args.steps} tokens @ {dt*1e3:.1f} ms/token")
    print("sampled ids:", out)


if __name__ == "__main__":
    main()
