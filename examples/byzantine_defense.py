"""Byzantine-defense showcase (paper Figs. 15-17 at reduced scale):
60% of workers are malicious — plain FedAvg collapses; geometric-median
defenses degrade past the 50% breakdown point; BR-DRAG keeps training.

    PYTHONPATH=src python examples/byzantine_defense.py [--attack sign_flipping]
"""
import argparse

from repro.fl import ExperimentConfig, run_experiment


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--attack", default="sign_flipping",
                    choices=["noise_injection", "sign_flipping", "label_flipping"])
    ap.add_argument("--malicious", type=float, default=0.6)
    ap.add_argument("--rounds", type=int, default=40)
    args = ap.parse_args()

    results = {}
    for alg in ["fedavg", "rfa", "fltrust", "br_drag"]:
        exp = ExperimentConfig(
            dataset="emnist",
            model="emnist_cnn",
            n_workers=20,
            n_selected=10,
            rounds=args.rounds,
            beta=0.1,
            algorithm=alg,
            attack=args.attack,
            malicious_fraction=args.malicious,
            c_br=0.5,
            eval_every=max(args.rounds // 4, 1),
            seed=1,
        )
        hist = run_experiment(exp)
        results[alg] = hist["final_accuracy"]
        print(f"{alg:10s}  acc curve {['%.3f' % a for a in hist['accuracy']]}")

    print(f"\n{args.attack} @ {int(args.malicious*100)}% malicious:")
    for alg, acc in sorted(results.items(), key=lambda kv: -kv[1]):
        bar = "#" * int(acc * 50)
        print(f"  {alg:10s} {acc:.3f} {bar}")


if __name__ == "__main__":
    main()
