"""Byzantine-defense showcase (paper Figs. 15-17 at reduced scale):
60% of workers are malicious — plain FedAvg collapses; geometric-median
defenses degrade past the 50% breakdown point; BR-DRAG keeps training.

Each run is one declarative ``repro.api.ExperimentSpec``; the sweep is
a list comprehension over the aggregation sub-spec.

    PYTHONPATH=src python examples/byzantine_defense.py [--attack sign_flipping]
"""
import argparse
import dataclasses

from repro.api import (
    AggregationSpec,
    AttackSpec,
    DataSpec,
    ExperimentSpec,
    ModelSpec,
    SyncRegime,
    compile,
)

ALGORITHMS = ["fedavg", "rfa", "fltrust", "br_drag"]


def specs(
    attack: str = "sign_flipping", malicious: float = 0.6, rounds: int = 40
) -> list[tuple[str, ExperimentSpec]]:
    base = ExperimentSpec(
        data=DataSpec(
            dataset="emnist", n_workers=20, beta=0.1, malicious_fraction=malicious
        ),
        model=ModelSpec("emnist_cnn"),
        attack=AttackSpec(attack),
        regime=SyncRegime(
            rounds=rounds, n_selected=10, eval_every=max(rounds // 4, 1)
        ),
        seed=1,
    )
    return [
        (alg, dataclasses.replace(base, aggregation=AggregationSpec(alg, c_br=0.5)))
        for alg in ALGORITHMS
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--attack", default="sign_flipping",
                    choices=["noise_injection", "sign_flipping", "label_flipping"])
    ap.add_argument("--malicious", type=float, default=0.6)
    ap.add_argument("--rounds", type=int, default=40)
    args = ap.parse_args()

    results = {}
    for alg, spec in specs(args.attack, args.malicious, args.rounds):
        hist = compile(spec).run()
        results[alg] = hist["final_accuracy"]
        print(f"{alg:10s}  acc curve {['%.3f' % a for a in hist['accuracy']]}")

    print(f"\n{args.attack} @ {int(args.malicious*100)}% malicious:")
    for alg, acc in sorted(results.items(), key=lambda kv: -kv[1]):
        bar = "#" * int(acc * 50)
        print(f"  {alg:10s} {acc:.3f} {bar}")


if __name__ == "__main__":
    main()
