"""Adversary lab tour: adaptive attacks vs. divergence-history trust.

Three acts on the synthetic least-squares federation (fast enough to
watch live):

  1. a sync attack x aggregator slice — watch FedAvg break while
     trust-weighted BR-DRAG holds;
  2. an attack SCHEDULE (sign flipping that switches to ALIE mid-run)
     against the same defenses;
  3. the async-native attacks (buffer_flood, staleness_camouflage)
     through the real event-driven stream engine.

    PYTHONPATH=src python examples/adversary_lab.py [--rounds 40]
"""
import argparse

from repro.adversary.scenarios import (
    Scenario,
    run_scenario,
    run_stream_scenario,
    stream_spec,
    sync_spec,
)


def specs(rounds: int = 40) -> list:
    """Every cell of the tour as a declarative ``ExperimentSpec``
    (validated by the spec-matrix CI job without running anything)."""
    out = []
    for attack, kw in [("alie", ()), ("ipm", (("eps", 2.0),)),
                       ("min_max", ()), ("mimic", ())]:
        for agg in ("fedavg", "median", "br_drag_trust"):
            sc = Scenario(aggregator=agg, attack=attack, attack_kw=kw, rounds=rounds)
            out.append((f"lab/act1/{attack}/{agg}", sync_spec(sc)))
    kw = (("phases", ((0, "sign_flipping"), (rounds // 2, "alie"))),)
    for agg in ("fedavg", "br_drag_trust"):
        sc = Scenario(aggregator=agg, attack="schedule", attack_kw=kw, rounds=rounds)
        out.append((f"lab/act2/schedule/{agg}", sync_spec(sc)))
    for attack in ("buffer_flood", "staleness_camouflage"):
        for agg in ("fedavg", "br_drag_trust"):
            out.append((
                f"lab/act3/{attack}/{agg}",
                stream_spec(Scenario(aggregator=agg, attack=attack)),
            ))
    return out


def bar(loss: float, floor: float = 1e-4, span: float = 8.0) -> str:
    import math

    if not math.isfinite(loss):
        return "#" * 40 + " (diverged)"
    n = int(40 * min(max(math.log10(loss / floor), 0.0), span) / span)
    return "#" * n


def act1(rounds: int) -> None:
    print("\n=== act 1: adaptive attacks, 40% byzantine ===")
    attacks = [("alie", ()), ("ipm", (("eps", 2.0),)), ("min_max", ()), ("mimic", ())]
    for attack, kw in attacks:
        print(f"\n  attack: {attack}")
        for agg in ("fedavg", "median", "br_drag_trust"):
            r = run_scenario(Scenario(
                aggregator=agg, attack=attack, attack_kw=kw, rounds=rounds,
            ))
            print(f"    {agg:14s} final_loss={r['final_loss']:10.4g} {bar(r['final_loss'])}")


def act2(rounds: int) -> None:
    print("\n=== act 2: attack schedule (sign_flipping -> alie at t=%d) ===" % (rounds // 2))
    kw = (("phases", ((0, "sign_flipping"), (rounds // 2, "alie"))),)
    for agg in ("fedavg", "br_drag_trust"):
        r = run_scenario(Scenario(
            aggregator=agg, attack="schedule", attack_kw=kw, rounds=rounds,
        ))
        print(f"    {agg:14s} final_loss={r['final_loss']:10.4g} {bar(r['final_loss'])}")


def act3() -> None:
    print("\n=== act 3: async-native attacks through the stream engine ===")
    for attack in ("buffer_flood", "staleness_camouflage"):
        print(f"\n  attack: {attack}")
        for agg in ("fedavg", "br_drag_trust"):
            r = run_stream_scenario(Scenario(aggregator=agg, attack=attack))
            print(f"    {agg:14s} final_loss={r['final_loss']:10.4g} {bar(r['final_loss'])}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    args = ap.parse_args()
    act1(args.rounds)
    act2(args.rounds)
    act3()
    print("\nfull matrix: PYTHONPATH=src python benchmarks/robustness_bench.py --smoke")


if __name__ == "__main__":
    main()
