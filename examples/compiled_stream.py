"""Compiled serving loop walkthrough — the device-resident megastep.

    PYTHONPATH=src python examples/compiled_stream.py

The legacy async loop drives ONE arrival at a time through jit
boundaries; at small model sizes ~99% of its wall clock is host
dispatch.  ``repro.stream.megastep`` compiles the loop itself — event
heap, local training, batched ingest, threshold flush, root-reference
schedule, trust/monitor update, telemetry ring, all inside one
``lax.scan``.  This tour shows:

  1. the spec-plane switch: ``AsyncRegime(compiled=True)`` — same
     experiment, same history keys, one field;
  2. what the fusion buys: legacy vs compiled updates/wall-s on the
     identical workload (compile time included — a deployment pays it
     once);
  3. the correctness contract: megastep(block=1) replays the per-event
     host loop BIT FOR BIT (params, drops, per-flush metrics), because
     both read the same hash-derived event/batch/latency plane;
  4. the megastep boundary: what rides the scan carry, what is
     precomputed per chunk, what stays at the host boundary (see
     ROADMAP "Compiled serving loop").
"""
import dataclasses
import time

import jax
import numpy as np

from repro.api import (
    AggregationSpec,
    AsyncRegime,
    DataSpec,
    ExperimentSpec,
    ModelSpec,
    TelemetrySpec,
    TrustSpec,
)
from repro.api import compile as api_compile


def banner(s):
    print(f"\n=== {s} " + "=" * max(8, 60 - len(s)))


def main():
    base = ExperimentSpec(
        data=DataSpec(dataset="emnist", n_workers=10, beta=0.5,
                      malicious_fraction=0.3),
        model=ModelSpec("mlp"),
        aggregation=AggregationSpec(algorithm="drag"),
        trust=TrustSpec(enabled=True),
        telemetry=TelemetrySpec(enabled=True),
        # enough flushes that the megastep's one-time trace amortises —
        # at toy scale the compile IS the cost, and a serving deployment
        # pays it exactly once
        regime=AsyncRegime(
            flushes=600, concurrency=8, buffer_capacity=4,
            latency="exponential", local_steps=2, batch_size=4,
            discount="poly", eval_every=300,
        ),
        seed=0,
    )

    banner("1. one declarative switch: AsyncRegime(compiled=True)")
    compiled_spec = dataclasses.replace(
        base, regime=dataclasses.replace(base.regime, compiled=True)
    ).validate()
    print("  regime:", compiled_spec.regime.kind,
          "compiled =", compiled_spec.regime.compiled,
          "| block = buffer_capacity, chunk = eval_every (the defaults)")

    banner("2. legacy loop vs compiled megastep, same workload")
    t0 = time.time()
    h_legacy = api_compile(base).run()
    legacy_s = time.time() - t0
    t0 = time.time()
    h_comp = api_compile(compiled_spec).run()
    comp_s = time.time() - t0
    print(f"  legacy  : {h_legacy['updates_total']} updates in {legacy_s:5.1f}s "
          f"-> {h_legacy['updates_per_wall_s']:7.1f} upd/s")
    print(f"  compiled: {h_comp['updates_total']} updates in {comp_s:5.1f}s "
          f"-> {h_comp['updates_per_wall_s']:7.1f} upd/s (incl. compile)")
    spans = h_comp["telemetry"]["spans"]
    ms = spans["megastep"]
    n_chunks = int(ms["count"])
    print("  compiled chunks ran as", n_chunks,
          "megastep span(s); host touched the loop once per chunk")
    if n_chunks > 1:
        # the longest span carries the one-time trace; the rest are the
        # steady state a serving deployment actually runs at
        warm_s = ms["total_ms"] / 1e3 - ms["max_us"] / 1e6
        warm_updates = h_comp["updates_total"] * (n_chunks - 1) / n_chunks
        print(f"  warm megastep rate (compile excluded): "
              f"{warm_updates / warm_s:7.1f} upd/s")

    banner("3. the contract: block=1 replays the host loop bit for bit")
    # the megastep flushes through the UNCHANGED server.flush, and the
    # hash-mode event plane (counter-keyed hashes + the block-drawn f32
    # arrivals table) is shared by both drivers, so the per-event
    # oracle in tests/test_megastep.py pins params, drop counters,
    # every per-flush metric, the trust table and the telemetry ring.
    for k in ("flush", "accuracy"):
        print(f"  history[{k!r}]  legacy={h_legacy[k]}  compiled={h_comp[k]}")
    # NOTE: legacy (mt-sampler) and compiled (hash-sampler) histories
    # agree in SHAPE, not bits — the bit-for-bit twin of the compiled
    # run is serve_unrolled, the per-event driver of the same hash
    # regime.  Accuracies land close because the workload is identical
    # in distribution:
    da = max(abs(a - b) for a, b in zip(h_legacy["accuracy"], h_comp["accuracy"]))
    print(f"  max |accuracy diff| across evals: {da:.3f}")

    banner("4. the megastep boundary (ROADMAP 'Compiled serving loop')")
    print("  scan carry : params, drag, buffer, adversary, trust, monitor,")
    print("               key, event heap + snapshots, root reference, ring")
    print("  chunk xs   : arrivals slice, root-batch stack, refresh schedule")
    print("  host, once per chunk: eval, ring drain, alert decode, span")
    tel = h_comp["telemetry"]
    print("  drained ring bundles:", tel["flushes_recorded"],
          "| drops_total:", tel["drops_total"])


if __name__ == "__main__":
    main()
